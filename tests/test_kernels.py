"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes, and hypothesis property tests."""

import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.common import SENTINEL
from repro.kernels.intersect.ops import intersect_sorted, plan_k_tiles as plan_k_int
from repro.kernels.intersect.ref import intersect_mask_ref
from repro.kernels.proximity.ops import proximity_join, plan_k_tiles as plan_k_prox
from repro.kernels.proximity.ref import proximity_join_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref


def _sorted_unique(rng, n, hi):
    return np.unique(rng.integers(0, hi, n).astype(np.int32))


# ---------------- intersect -------------------------------------------------
@pytest.mark.parametrize("na,nb,hi", [
    (100, 100, 500),       # dense overlap
    (1000, 5000, 20000),   # skewed sizes
    (5000, 700, 100000),   # sparse overlap
    (513, 1025, 4000),     # non-multiple-of-block sizes
    (3, 2, 10),            # tiny
])
def test_intersect_vs_ref_shapes(na, nb, hi):
    rng = np.random.default_rng(na * 7 + nb)
    a = _sorted_unique(rng, na, hi)
    b = _sorted_unique(rng, nb, hi)
    k = plan_k_int(a, b)
    mask, idx = intersect_sorted(jnp.asarray(a), jnp.asarray(b), k_tiles=k)
    want = np.isin(a, b)
    np.testing.assert_array_equal(np.asarray(mask), want)
    # idx must point at the matching value in padded b
    b_pad = np.concatenate([b, np.full((-len(b)) % 1024, SENTINEL, np.int32)])
    got_idx = np.asarray(idx)
    assert np.all(b_pad[got_idx[want]] == a[want])


def test_intersect_ref_matches_numpy():
    rng = np.random.default_rng(0)
    a = _sorted_unique(rng, 400, 2000)
    b = _sorted_unique(rng, 300, 2000)
    mask = intersect_mask_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(mask), np.isin(a, b))


@given(
    st.lists(st.integers(0, 300), max_size=60),
    st.lists(st.integers(0, 300), max_size=60),
)
@settings(max_examples=40, deadline=None)
def test_intersect_property(xs, ys):
    a = np.unique(np.array(xs + [0], np.int32))
    b = np.unique(np.array(ys + [0], np.int32))
    mask, _ = intersect_sorted(jnp.asarray(a), jnp.asarray(b), block_a=8, block_b=16,
                               k_tiles=plan_k_int(a, b, 8, 16))
    np.testing.assert_array_equal(np.asarray(mask), np.isin(a, b))


def test_intersect_full_scan_default_k():
    rng = np.random.default_rng(3)
    a = _sorted_unique(rng, 600, 3000)
    b = _sorted_unique(rng, 900, 3000)
    mask, _ = intersect_sorted(jnp.asarray(a), jnp.asarray(b))  # k_tiles=None
    np.testing.assert_array_equal(np.asarray(mask), np.isin(a, b))


# ---------------- proximity -------------------------------------------------
@pytest.mark.parametrize("d", [1, 5, 7, 9])
@pytest.mark.parametrize("na,nb", [(200, 300), (1100, 600)])
def test_proximity_vs_ref(d, na, nb):
    rng = np.random.default_rng(d * 101 + na)
    a = _sorted_unique(rng, na, 8000)
    b = _sorted_unique(rng, nb, 8000)
    k = plan_k_prox(a, b, d)
    mask, lo, hi = proximity_join(jnp.asarray(a), jnp.asarray(b), d, k_tiles=k)
    rmask, rlo, rhi = proximity_join_ref(jnp.asarray(a), jnp.asarray(b), d)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))
    m = np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(lo)[m], np.asarray(rlo)[m])
    np.testing.assert_array_equal(np.asarray(hi)[m], np.asarray(rhi)[m])


def test_proximity_ref_matches_bruteforce():
    rng = np.random.default_rng(1)
    a = _sorted_unique(rng, 80, 400)
    b = _sorted_unique(rng, 60, 400)
    d = 5
    mask, lo, hi = proximity_join_ref(jnp.asarray(a), jnp.asarray(b), d)
    for i, av in enumerate(a.tolist()):
        near = b[(b >= av - d) & (b <= av + d)]
        assert bool(mask[i]) == (near.size > 0)
        if near.size:
            assert int(lo[i]) == near.min() and int(hi[i]) == near.max()


# ---------------- embedding bag ---------------------------------------------
@pytest.mark.parametrize("B,S,V,D", [
    (32, 8, 100, 16),
    (130, 5, 513, 32),   # non-multiples
    (8, 1, 2000, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_vs_ref(B, S, V, D, dtype):
    rng = np.random.default_rng(B + V)
    ids = rng.integers(-1, V, (B, S)).astype(np.int32)
    table = rng.normal(size=(V, D)).astype(np.float32)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    out_k = embedding_bag(jnp.asarray(ids), jnp.asarray(table, dtype), use_pallas=True,
                          block_b=32, block_v=128)
    out_r = embedding_bag_ref(jnp.asarray(ids), jnp.asarray(table, dtype))
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), rtol=tol, atol=tol * 10
    )


def test_embedding_bag_weights_and_mean():
    rng = np.random.default_rng(7)
    B, S, V, D = 16, 6, 50, 8
    ids = rng.integers(-1, V, (B, S)).astype(np.int32)
    w = rng.normal(size=(B, S)).astype(np.float32)
    table = rng.normal(size=(V, D)).astype(np.float32)
    for combine in ("sum", "mean"):
        out_k = embedding_bag(jnp.asarray(ids), jnp.asarray(table), jnp.asarray(w),
                              combine, use_pallas=True, block_b=8, block_v=16)
        out_r = embedding_bag_ref(jnp.asarray(ids), jnp.asarray(table), jnp.asarray(w), combine)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


def test_embedding_bag_ref_manual():
    table = jnp.asarray(np.eye(4, dtype=np.float32))
    ids = jnp.asarray(np.array([[0, 1, -1], [2, 2, 3]], np.int32))
    out = embedding_bag_ref(ids, table)
    np.testing.assert_array_equal(
        np.asarray(out), np.array([[1, 1, 0, 0], [0, 0, 2, 1]], np.float32)
    )


# ---------------- compressed-stream intersect (in-kernel decode) -----------
@pytest.mark.parametrize("na,nb,hi", [
    (300, 500, 4000),
    (1000, 2000, 30000),
    (70, 1500, 9000),
])
def test_intersect_compressed_vs_numpy(na, nb, hi):
    from repro.kernels.intersect.ops import intersect_sorted_compressed

    rng = np.random.default_rng(na + nb)
    a = _sorted_unique(rng, na, hi)
    b = _sorted_unique(rng, nb, hi)
    mask = intersect_sorted_compressed(a, b, block_a=128, block_b=256)
    np.testing.assert_array_equal(np.asarray(mask), np.isin(a, b))


def test_pack_delta_stream_roundtrip():
    from repro.kernels.intersect.intersect import DELTA_BLK, PAD_DELTA
    from repro.kernels.intersect.ops import pack_delta_stream

    rng = np.random.default_rng(0)
    x = np.unique(rng.integers(0, 10_000, 500)).astype(np.int32)
    base, delta = pack_delta_stream(x, 1024)
    rec = np.repeat(base, DELTA_BLK).astype(np.int64) + delta
    valid = delta != PAD_DELTA
    np.testing.assert_array_equal(rec[valid][: x.size], x)
    assert valid.sum() == x.size
