"""Segmented incremental indexing (repro.index): cross-engine equivalence
after adds/deletes/compactions, persistence round-trips, compaction
policy, and live-refresh serving.

The load-bearing property: a ``SegmentedIndex`` that absorbed the corpus
through any sequence of memtable seals, tombstone deletes, size-tiered
merges and forced compactions must answer QT1-QT5 *identically* (modulo
the global->compact doc-id remap) to a from-scratch ``build_index`` over
the final corpus — the response-time-guarantee structures may never
drift under churn.
"""

import numpy as np
import pytest

from repro.core.index_builder import build_index, build_segment_index
from repro.core.search import InvertedIndexEngine, ProximitySearchEngine
from repro.data.corpus import TokenTable, generate_corpus
from repro.index import SegmentedIndex, load_index, save_index, size_tiered_plan

D = 5


def _doc_tokens(table):
    return table.to_doc_lists()


@pytest.fixture(scope="module")
def churned_world():
    """90 docs streamed through small memtables; 12 deleted mid-stream;
    tiered merges run along the way and a major compaction at the end."""
    table, lex = generate_corpus(n_docs=90, mean_doc_len=60, vocab_size=400, seed=3)
    lex.sw_count = 12
    lex.fu_count = 25
    docs = _doc_tokens(table)

    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=10, tier_fanout=3)
    for d in docs[:60]:
        seg.add_document(d)
    seg.refresh()
    rng = np.random.default_rng(7)
    dead = sorted(rng.choice(60, size=12, replace=False).tolist())
    for g in dead:
        seg.delete_document(g)
    for d in docs[60:]:
        seg.add_document(d)
    seg.refresh()
    seg.compact(force=True)
    view = seg.refresh()

    live = view.live_doc_ids()
    final_docs = [np.array(docs[int(g)], np.int32) for g in live]
    ftable = TokenTable.from_docs(final_docs)
    ref_idx = build_index(ftable, lex, max_distance=D)
    remap = {int(g): i for i, g in enumerate(live.tolist())}
    return seg, view, lex, ftable, ref_idx, remap, set(dead)


def _sample_query(ftable, lex, want, seed):
    rng = np.random.default_rng(seed)
    sw, fu = lex.sw_count, lex.fu_count
    for _ in range(4000):
        r = int(rng.integers(0, ftable.n_rows))
        d0, p0 = int(ftable.doc_ids[r]), int(ftable.positions[r])
        m = (ftable.doc_ids == d0) & (np.abs(ftable.positions - p0) <= D)
        lems = np.unique(ftable.lemma_ids[m])
        stop = lems[lems < sw]
        freq = lems[(lems >= sw) & (lems < sw + fu)]
        ordi = lems[lems >= sw + fu]
        if want == "qt1" and stop.size >= 3:
            return sorted(rng.choice(stop, 3, replace=False).tolist())
        if want == "qt2" and freq.size >= 2:
            return sorted(rng.choice(freq, 2, replace=False).tolist())
        if want == "qt3" and ordi.size >= 2:
            return sorted(rng.choice(ordi, 2, replace=False).tolist())
        if want == "qt4" and freq.size >= 1 and ordi.size >= 1:
            return sorted([int(rng.choice(freq)), int(rng.choice(ordi))])
        if want == "qt5" and stop.size >= 1 and freq.size + ordi.size >= 2:
            ns = np.concatenate([freq, ordi])
            return sorted(rng.choice(ns, 2, replace=False).tolist() + [int(rng.choice(stop))])
    return None


def _records(matches, remap=None):
    docs = matches.doc.tolist()
    if remap is not None:
        docs = [remap[int(x)] for x in docs]
    return sorted(
        zip(docs, matches.start.tolist(), matches.end.tolist(),
            np.round(matches.score, 9).tolist())
    )


@pytest.mark.parametrize("want", ["qt1", "qt2", "qt3", "qt4", "qt5"])
def test_cross_engine_equivalence(churned_world, want):
    """Segmented + compacted == fresh rebuild, full (ID, P, E, R) records."""
    seg, view, lex, ftable, ref_idx, remap, _ = churned_world
    eng_seg = ProximitySearchEngine(view, top_k=10_000)
    eng_ref = ProximitySearchEngine(ref_idx, top_k=10_000)
    tested = 0
    for trial in range(4):
        q = _sample_query(ftable, lex, want, seed=100 + 31 * trial + ord(want[-1]))
        if q is None:
            continue
        r_ref, _ = eng_ref.search_ids(q)
        r_seg, _ = eng_seg.search_ids(q)
        assert _records(r_ref) == _records(r_seg, remap), (want, q)
        tested += 1
    assert tested > 0, f"no {want} query sampled"


def test_idx1_baseline_equivalence(churned_world):
    seg, view, lex, ftable, ref_idx, remap, _ = churned_world
    b_ref = InvertedIndexEngine(ref_idx, top_k=10_000)
    b_seg = InvertedIndexEngine(view, top_k=10_000)
    q = _sample_query(ftable, lex, "qt1", seed=999)
    r1, _ = b_ref.search_ids(q)
    r2, _ = b_seg.search_ids(q)
    assert _records(r1) == _records(r2, remap)


def test_deleted_docs_not_served(churned_world):
    seg, view, lex, ftable, ref_idx, remap, dead = churned_world
    assert not (set(int(g) for g in view.live_doc_ids()) & dead)
    eng = ProximitySearchEngine(view, top_k=10_000)
    for trial in range(3):
        q = _sample_query(ftable, lex, "qt1", seed=55 + trial)
        r, _ = eng.search_ids(q)
        assert not (set(int(x) for x in r.doc) & dead)


def test_single_shot_build_is_one_segment():
    """build_index routes through MemSegment; output must equal the direct
    segment build bit-for-bit (same blobs, same sizes)."""
    table, lex = generate_corpus(n_docs=30, mean_doc_len=40, vocab_size=300, seed=5)
    lex.sw_count = 10
    lex.fu_count = 20
    i1 = build_index(table, lex, max_distance=D)
    i2 = build_segment_index(table, lex, max_distance=D)
    assert i1.size_report() == i2.size_report()
    for l in list(i1.ordinary.keys())[:20]:
        for a, b in zip(i1.read_ordinary(l), i2.read_ordinary(l)):
            assert np.array_equal(a, b)


def test_refresh_visibility():
    """Adds are invisible until refresh(); snapshots are stable."""
    table, lex = generate_corpus(n_docs=20, mean_doc_len=40, vocab_size=300, seed=11)
    lex.sw_count = 10
    lex.fu_count = 20
    docs = _doc_tokens(table)
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=100)
    for d in docs[:10]:
        seg.add_document(d)
    v1 = seg.refresh()
    n1 = v1.live_doc_ids().size
    for d in docs[10:]:
        seg.add_document(d)
    # not yet refreshed: the published snapshot is unchanged
    assert seg.snapshot() is v1
    assert seg.snapshot().live_doc_ids().size == n1
    v2 = seg.refresh()
    assert v2.live_doc_ids().size == len(docs)
    # old snapshot still consistent (immutable)
    assert v1.live_doc_ids().size == n1


def test_size_tiered_plan_and_auto_compaction():
    table, lex = generate_corpus(n_docs=64, mean_doc_len=30, vocab_size=300, seed=13)
    lex.sw_count = 10
    lex.fu_count = 20
    docs = _doc_tokens(table)
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=4, tier_fanout=4)
    for d in docs:
        seg.add_document(d)
    seg.refresh()
    # 64 docs / 4-doc memtables = 16 seals; fanout-4 tiering must have
    # merged repeatedly and kept the live segment count well below that
    assert seg.stats["seals"] == 16
    assert seg.stats["merges"] >= 1
    assert seg.n_segments < 16
    assert not size_tiered_plan(seg._segments, seg.tier_fanout)


def test_multi_tier_plan_merges_without_staleness():
    """Two tiers due simultaneously: maybe_compact must replan after each
    merge (stale indices once crashed / could duplicate docs)."""
    _, lex = generate_corpus(n_docs=5, mean_doc_len=10, vocab_size=100, seed=1)
    lex.sw_count = 5
    lex.fu_count = 10
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=10**9, tier_fanout=3)

    def seal_batch(docs):
        mem = seg._new_mem()
        base = seg._next_doc
        for i, t in enumerate(docs):
            mem.add_document(base + i, t)
        seg._next_doc = base + len(docs)
        seg._segments.append(mem.seal(seg._next_seg))
        seg._next_seg += 1

    for _ in range(3):
        seal_batch([[1, 2, 3]] * 2)  # small tier
    for _ in range(3):
        seal_batch([[k % 50 for k in range(400)]] * 2)  # big tier
    assert len(size_tiered_plan(seg._segments, 3)) >= 2
    seg.maybe_compact()
    view = seg.refresh()
    all_docs = np.concatenate([s.doc_map for s in seg._segments])
    assert np.unique(all_docs).size == all_docs.size == seg._next_doc
    assert view.live_doc_ids().size == seg._next_doc


def test_delete_idempotent_across_compaction():
    """Re-deleting a doc whose tombstone was purged by compaction must not
    resurrect an unpurgeable tombstone."""
    table, lex = generate_corpus(n_docs=12, mean_doc_len=20, vocab_size=200, seed=2)
    lex.sw_count = 8
    lex.fu_count = 16
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=4)
    gids = [seg.add_document(d) for d in _doc_tokens(table)]
    seg.refresh()
    seg.delete_document(gids[0])
    deleted_count = seg.stats["docs_deleted"]
    seg.compact(force=True)
    view = seg.refresh()
    assert view.tombstones.size == 0  # purged by the merge
    seg.delete_document(gids[0])  # retry: must be a no-op
    seg.delete_document(gids[0])
    assert seg.refresh().tombstones.size == 0
    assert seg.stats["docs_deleted"] == deleted_count
    assert seg.refresh().live_doc_ids().size == len(gids) - 1


def test_segmented_save_load_roundtrip(tmp_path, churned_world):
    seg, view, lex, ftable, ref_idx, remap, _ = churned_world
    seg.save(tmp_path / "idx")
    seg2 = SegmentedIndex.load(tmp_path / "idx")
    v2 = seg2.refresh()
    assert np.array_equal(view.live_doc_ids(), v2.live_doc_ids())
    eng1 = ProximitySearchEngine(view, top_k=10_000)
    eng2 = ProximitySearchEngine(v2, top_k=10_000)
    for want in ("qt1", "qt5"):
        q = _sample_query(ftable, lex, want, seed=77)
        r1, s1 = eng1.search_ids(q)
        r2, s2 = eng2.search_ids(q)
        assert _records(r1) == _records(r2)
        assert s1.bytes_read == s2.bytes_read  # identical encoded blobs


def test_plain_index_save_load_roundtrip(tmp_path):
    table, lex = generate_corpus(n_docs=30, mean_doc_len=40, vocab_size=300, seed=5)
    lex.sw_count = 10
    lex.fu_count = 20
    idx = build_index(table, lex, max_distance=D)
    save_index(idx, tmp_path / "plain")
    idx2 = load_index(tmp_path / "plain")
    assert idx.size_report() == idx2.size_report()
    eng1 = ProximitySearchEngine(idx, top_k=1000)
    eng2 = ProximitySearchEngine(idx2, top_k=1000)
    stop = [l for l in range(lex.sw_count)][:3]
    r1, _ = eng1.search_ids(stop)
    r2, _ = eng2.search_ids(stop)
    assert _records(r1) == _records(r2)


def test_serving_refresh_protocol(churned_world):
    """The bucketed JAX serve path runs unchanged over SegmentedIndex and
    picks up new/deleted docs via engine.refresh()."""
    from repro.launch.mesh import make_mesh
    from repro.serving.engine import SearchServingEngine

    seg, view, lex, ftable, ref_idx, remap, _ = churned_world
    mesh = make_mesh((1, 1), ("data", "model"))
    eng = SearchServingEngine(seg, mesh, buckets=(256, 1024, 4096), max_batch=8, top_k=16)
    ref = ProximitySearchEngine(view, top_k=16, equalize_mode="bulk")
    served = 0
    for trial in range(4):
        q = _sample_query(ftable, lex, "qt1", seed=300 + trial)
        if q is None:
            continue
        eng.submit(q)
        (resp,) = eng.drain()
        want, _ = ref.search_ids(q)
        got = set(zip(resp.results["doc"].tolist(), resp.results["start"].tolist()))
        assert got <= set(zip(want.doc.tolist(), want.start.tolist()))
        if want.size:
            assert got
        served += 1
    assert served > 0
    # live refresh: delete a doc that was being served; re-drain sees it gone
    q = _sample_query(ftable, lex, "qt1", seed=301)
    eng.submit(q)
    (resp,) = eng.drain()
    if resp.results["doc"].size:
        victim = int(resp.results["doc"][0])
        seg.delete_document(victim)
        seg.refresh()
        eng.refresh()
        eng.submit(q)
        (resp2,) = eng.drain()
        assert victim not in set(resp2.results["doc"].tolist())
