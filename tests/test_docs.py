"""Docs-consistency checks (the CI docs step): DESIGN.md section
references in source comments must resolve to real sections, README
commands must point at real entrypoints, the §13 dispatch-matrix table
must cover every query type, and the tracked bench report must cover
every dispatch route. Pure-stdlib so the CI lint job can run it without
installing jax."""

import json
import re
import shlex
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _design_sections() -> set[int]:
    text = (REPO / "DESIGN.md").read_text()
    return {int(n) for n in re.findall(r"^## §(\d+)\b", text, re.M)}


def test_design_sections_contiguous():
    sections = _design_sections()
    assert sections, "DESIGN.md has no '## §N' sections"
    assert sections == set(range(1, max(sections) + 1)), sections


def test_design_refs_in_source_resolve():
    """Every `DESIGN.md §N` (incl. `§A-§B` / `§A/§B` forms) written in a
    source comment or docstring names a section that actually exists —
    dangling references rot fastest exactly where they are most relied
    on."""
    sections = _design_sections()
    bad = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for path in sorted((REPO / sub).rglob("*.py")):
            for ln, line in enumerate(path.read_text().splitlines(), 1):
                if "DESIGN.md" not in line:
                    continue
                tail = line.split("DESIGN.md", 1)[1]
                for ref in re.findall(r"§(\d+)", tail):
                    if int(ref) not in sections:
                        bad.append((str(path.relative_to(REPO)), ln, f"§{ref}"))
    assert not bad, f"dangling DESIGN.md references: {bad}"


def _readme_commands() -> list[str]:
    text = (REPO / "README.md").read_text()
    cmds = []
    for block in re.findall(r"```bash\n(.*?)```", text, re.S):
        for line in block.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                cmds.append(line)
    return cmds


def test_readme_exists_with_required_commands():
    text = (REPO / "README.md").read_text()
    assert "PYTHONPATH=src python -m pytest -x -q" in text  # the tier-1 command
    assert "BENCH_serve.json" in text  # how to regenerate the bench report
    assert "DESIGN.md" in text and "PAPER.md" in text


def test_readme_commands_smoke_parse():
    """Every command in a README ```bash block must invoke a script that
    exists (or a `python -m` module target) — a README whose quickstart
    400s is worse than none."""
    cmds = _readme_commands()
    assert cmds, "README has no bash code blocks"
    for cmd in cmds:
        argv = shlex.split(cmd)
        while argv and re.fullmatch(r"[A-Z_]+=\S*", argv[0]):
            argv.pop(0)  # env assignments like PYTHONPATH=src:.
        if argv[0] == "pip":
            continue
        assert argv[0] == "python", cmd
        if argv[1] == "-m":
            mod = argv[2]
            assert mod in ("pytest", "pydoc") or (
                REPO / "src" / Path(*mod.split("."))).exists(), cmd
        else:
            assert (REPO / argv[1]).exists(), cmd


def test_dispatch_matrix_covers_all_query_types():
    """DESIGN.md §13's dispatch-matrix table (the replacement for the
    stale prose that used to live in serving/engine.py) must keep one
    row per query type of the paper."""
    text = (REPO / "DESIGN.md").read_text()
    s13 = text.split("## §13", 1)[1]
    table_rows = [l for l in s13.splitlines() if l.startswith("|")]
    assert len(table_rows) >= 7  # header + separator + QT1-5 rows
    body = "\n".join(table_rows)
    for qt in ("QT1", "QT2", "QT3", "QT4", "QT5"):
        assert re.search(rf"^\| {qt} ", body, re.M), f"no matrix row for {qt}"
    for route in ("`qt1`", "`qt2`", "`qt34`", "`qt5`"):
        assert route in body, f"no route column entry {route}"


# The bench-coverage assertions themselves live in
# benchmarks/check_bench_coverage.py (pure stdlib, shared with the CI
# bench step, which runs the same checkers on a freshly generated
# file); here they are applied per-section to the *committed*
# BENCH_serve.json so a PR cannot land a report that lost a subsystem.
def _coverage_failures(section: str) -> list:
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.check_bench_coverage import check_payload
    finally:
        sys.path.pop(0)
    payload = json.loads((REPO / "BENCH_serve.json").read_text())
    return check_payload(payload, [section])


def test_tracked_bench_report_covers_serve_section():
    """Dispatch routes, §14 planner layer, §15 phase observability,
    §16 payload choice, §17 multi-budget deadline rows."""
    assert _coverage_failures("serve") == []


def test_tracked_bench_report_covers_kernel_section():
    """§16 nearest-r kernel rows incl. the Pallas interpret
    bit-identity spot-check."""
    assert _coverage_failures("kernel") == []


def test_tracked_bench_report_covers_load_section():
    """§17 open-loop control loop: capacity probe + controlled vs
    uncontrolled met-rates on a shared trace."""
    assert _coverage_failures("load") == []


def test_tracked_bench_report_covers_churn_section():
    """§18 ingest tier: background compaction + live-memtable churn
    rows with at least one off-path merge."""
    assert _coverage_failures("churn") == []


def test_tracked_bench_report_covers_tune_section():
    """§19 autotuner: the sweep's space floor (>= 2 MaxDistance x >= 8
    serve configs), winner artifact + verdicts + sensitivity, and the
    per-workload tuned-vs-default p50 rows."""
    assert _coverage_failures("tune") == []
