"""Docs-consistency checks (the CI docs step): DESIGN.md section
references in source comments must resolve to real sections, README
commands must point at real entrypoints, the §13 dispatch-matrix table
must cover every query type, and the tracked bench report must cover
every dispatch route. Pure-stdlib so the CI lint job can run it without
installing jax."""

import json
import re
import shlex
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _design_sections() -> set[int]:
    text = (REPO / "DESIGN.md").read_text()
    return {int(n) for n in re.findall(r"^## §(\d+)\b", text, re.M)}


def test_design_sections_contiguous():
    sections = _design_sections()
    assert sections, "DESIGN.md has no '## §N' sections"
    assert sections == set(range(1, max(sections) + 1)), sections


def test_design_refs_in_source_resolve():
    """Every `DESIGN.md §N` (incl. `§A-§B` / `§A/§B` forms) written in a
    source comment or docstring names a section that actually exists —
    dangling references rot fastest exactly where they are most relied
    on."""
    sections = _design_sections()
    bad = []
    for sub in ("src", "tests", "benchmarks", "examples"):
        for path in sorted((REPO / sub).rglob("*.py")):
            for ln, line in enumerate(path.read_text().splitlines(), 1):
                if "DESIGN.md" not in line:
                    continue
                tail = line.split("DESIGN.md", 1)[1]
                for ref in re.findall(r"§(\d+)", tail):
                    if int(ref) not in sections:
                        bad.append((str(path.relative_to(REPO)), ln, f"§{ref}"))
    assert not bad, f"dangling DESIGN.md references: {bad}"


def _readme_commands() -> list[str]:
    text = (REPO / "README.md").read_text()
    cmds = []
    for block in re.findall(r"```bash\n(.*?)```", text, re.S):
        for line in block.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                cmds.append(line)
    return cmds


def test_readme_exists_with_required_commands():
    text = (REPO / "README.md").read_text()
    assert "PYTHONPATH=src python -m pytest -x -q" in text  # the tier-1 command
    assert "BENCH_serve.json" in text  # how to regenerate the bench report
    assert "DESIGN.md" in text and "PAPER.md" in text


def test_readme_commands_smoke_parse():
    """Every command in a README ```bash block must invoke a script that
    exists (or a `python -m` module target) — a README whose quickstart
    400s is worse than none."""
    cmds = _readme_commands()
    assert cmds, "README has no bash code blocks"
    for cmd in cmds:
        argv = shlex.split(cmd)
        while argv and re.fullmatch(r"[A-Z_]+=\S*", argv[0]):
            argv.pop(0)  # env assignments like PYTHONPATH=src:.
        if argv[0] == "pip":
            continue
        assert argv[0] == "python", cmd
        if argv[1] == "-m":
            mod = argv[2]
            assert mod in ("pytest", "pydoc") or (
                REPO / "src" / Path(*mod.split("."))).exists(), cmd
        else:
            assert (REPO / argv[1]).exists(), cmd


def test_dispatch_matrix_covers_all_query_types():
    """DESIGN.md §13's dispatch-matrix table (the replacement for the
    stale prose that used to live in serving/engine.py) must keep one
    row per query type of the paper."""
    text = (REPO / "DESIGN.md").read_text()
    s13 = text.split("## §13", 1)[1]
    table_rows = [l for l in s13.splitlines() if l.startswith("|")]
    assert len(table_rows) >= 7  # header + separator + QT1-5 rows
    body = "\n".join(table_rows)
    for qt in ("QT1", "QT2", "QT3", "QT4", "QT5"):
        assert re.search(rf"^\| {qt} ", body, re.M), f"no matrix row for {qt}"
    for route in ("`qt1`", "`qt2`", "`qt34`", "`qt5`"):
        assert route in body, f"no route column entry {route}"


def test_tracked_bench_report_covers_dispatch_routes():
    """BENCH_serve.json (regenerated per PR) must keep cold/warm rows
    for every compiled dispatch route plus the mixed drain — the CI
    bench step re-checks this on a freshly generated file."""
    payload = json.loads((REPO / "BENCH_serve.json").read_text())
    names = {r["name"] for r in payload["rows"]}
    for want in ("drain_qt2_", "drain_qt3_", "drain_qt4_", "drain_qt5_",
                 "drain_mixed_"):
        assert any(want in n for n in names), (want, sorted(names))
    typed = payload["reports"]["serve"]["drain_typed"]
    for key in ("qt3", "qt4", "qt3_compressed", "qt4_compressed"):
        assert {"cold", "warm"} <= typed[key].keys(), key


def test_tracked_bench_report_covers_planner_layer():
    """The §14 planner-layer metrics must stay in BENCH_serve.json: the
    deadline_met_rate row (the response-time guarantee as one number)
    and the per-route plan stats incl. dispatch-aware batching."""
    payload = json.loads((REPO / "BENCH_serve.json").read_text())
    names = {r["name"] for r in payload["rows"]}
    assert any("deadline_met_rate" in n for n in names), sorted(names)
    rep = payload["reports"]["serve"]
    assert {"budget_ms", "met_rate", "n"} <= rep["deadline"].keys()
    routes = rep["plans"]["routes"]
    for route in ("qt1", "qt2", "qt34", "qt5", "scalar"):
        assert route in routes, (route, routes)
    assert "executables" in rep["plans"] and "shared_batches" in rep["plans"]


def test_tracked_bench_report_covers_phase_observability():
    """The §15 phase rows must stay in BENCH_serve.json: one
    `serve/phase.*` row per request phase (value = p50 µs, p95 in the
    derived column), the per-request phase-sum-vs-e2e tiling check
    inside the 10% acceptance bound, deadline miss-phase attribution,
    and the planner's est-vs-measured calibration table."""
    payload = json.loads((REPO / "BENCH_serve.json").read_text())
    rows = {r["name"]: r for r in payload["rows"]}
    for ph in ("queue", "plan", "pack", "compress", "execute", "decode"):
        row = rows[f"serve/phase.{ph}"]
        assert "p95_us=" in row["derived"] and "count=" in row["derived"], row
    rep = payload["reports"]["serve"]
    assert rep["phases"]["per_request_sum_vs_e2e_max_rel_err"] < 0.10
    for ph in ("queue", "plan", "pack", "execute", "decode"):
        assert rep["phases"][ph]["p95_us"] >= rep["phases"][ph]["p50_us"] >= 0.0
    assert "serve/deadline_miss_phase" in rows
    assert "miss_blame" in rep["deadline"]
    assert rep["plans"]["est_vs_measured"], "measured-cost table is empty"


def test_tracked_bench_report_covers_nearest_r_and_payload_choice():
    """The §16 rows must stay in BENCH_serve.json: nearest-r kernel
    rows (counting join vs argsort baseline + the Pallas interpret
    spot-check, which must report bit-identity) and the per-route
    cost-driven payload-choice report."""
    payload = json.loads((REPO / "BENCH_serve.json").read_text())
    names = {r["name"] for r in payload["rows"]}
    for want in ("kernel/nearest_r_ref_", "kernel/nearest_r_count_",
                 "kernel/nearest_r_pallas_interp_", "serve/payload_choice_qt3",
                 "serve/payload_choice_qt4", "serve/payload_choice_qt5"):
        assert any(n.startswith(want) for n in names), (want, sorted(names))
    pallas = next(r for r in payload["rows"]
                  if r["name"].startswith("kernel/nearest_r_pallas_interp_"))
    assert "bit_identical_to_ref=1" in pallas["derived"], pallas
    pc = payload["reports"]["serve"]["payload_choice"]
    for route in ("qt3", "qt4", "qt5"):
        assert pc[route]["warm_ratio_vs_raw_engine"] > 0.0, (route, pc)
        assert pc[route]["chosen_within_5pct_of_alt"], (route, pc)
