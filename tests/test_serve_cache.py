"""Packed-posting serve cache + compressed serving pipeline (DESIGN.md §11):
cache-backed packing must be byte-identical to direct packing, warm and
cold drains must agree, the compressed engine must match the uncompressed
one over static and segmented (post-compaction) snapshots, and a
refresh() must invalidate cached rows (stale-cache regression).
"""

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.jax_search import (
    QT1Batch,
    batch_size_bucket,
    decode_results,
    pack_fst_key_rows,
    pack_qt1_batch,
)
from repro.data.corpus import generate_corpus, sample_stop_queries
from repro.index import SegmentedIndex, snapshot_token
from repro.launch.mesh import make_mesh
from repro.serving.engine import SearchServingEngine
from repro.serving.pack_cache import PackedPostingCache

D = 5
BUCKETS = (256, 1024)


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    queries = sample_stop_queries(table, lex, 10, window=5, seed=4)
    mesh = make_mesh((1, 1), ("data", "model"))
    return table, lex, idx, queries, mesh


def _sig(responses):
    return [
        sorted(zip(r.results["doc"].tolist(), r.results["start"].tolist(),
                   r.results["end"].tolist(),
                   np.round(r.results["score"], 5).tolist()))
        for r in responses
    ]


def _drain(eng, queries):
    for q in queries:
        eng.submit(q)
    resp = eng.drain()
    assert len(resp) == len(queries)
    return _sig(resp)


# -- packing ---------------------------------------------------------------
def test_pack_cached_equals_uncached(world):
    table, lex, idx, queries, mesh = world
    cache = PackedPostingCache()
    for _ in range(2):  # second pass: all rows come from the cache
        a = pack_qt1_batch(idx, queries, L=1024, K=2)
        b = pack_qt1_batch(idx, queries, L=1024, K=2, cache=cache)
        for f in ("key_g", "key_lo", "key_hi", "idf_sum", "span_adjust"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), f
        assert a.stride == b.stride
    assert cache.stats["hits"] > 0


def test_cache_lru_and_stats(world):
    table, lex, idx, queries, mesh = world
    keys = [k for k in idx.fst.keys()][:6]
    cache = PackedPostingCache(max_entries=4)
    for key in keys:
        cache.get_rows(idx, key, 256, 1)
    st = cache.stats
    assert st["misses"] == 6 and st["entries"] == 4 and st["evictions"] == 2
    assert st["bytes"] == 4 * 3 * 256 * 4  # entries * rows * L * int32
    # evicted keys miss again; resident keys hit
    cache.get_rows(idx, keys[-1], 256, 1)
    assert cache.stats["hits"] == 1
    cache.get_rows(idx, keys[0], 256, 1)
    assert cache.stats["misses"] == 7


def test_cache_rows_match_direct_derivation(world):
    table, lex, idx, queries, mesh = world
    cache = PackedPostingCache()
    key = next(iter(idx.fst.keys()))
    g, lo, hi, present = cache.get_rows(idx, key, 512, 1)
    dg, dlo, dhi, dpresent = pack_fst_key_rows(idx, key, 512, 1)
    assert present == dpresent
    assert np.array_equal(g, dg) and np.array_equal(lo, dlo) and np.array_equal(hi, dhi)
    assert not g.flags.writeable  # shared rows must be immutable
    missing = (10**6, 10**6 + 1, 10**6 + 2)
    bytes_before = cache.stats["bytes"]
    mg, mlo, mhi, present = cache.get_rows(idx, missing, 512, 1)
    assert not present
    # negative entries share one SENTINEL row and must cost 0 bytes
    assert mg is mlo is mhi
    assert cache.stats["bytes"] == bytes_before
    assert cache.get_rows(idx, missing, 512, 1)[3] is False  # cached hit
    assert cache.stats["hits"] == 1
    assert cache.stats["negative_entries"] == 1


def test_absent_key_churn_does_not_evict_hot_rows(world):
    """Negative entries live in their own LRU: a stream of distinct
    absent keys must not displace cached positive rows."""
    table, lex, idx, queries, mesh = world
    cache = PackedPostingCache(max_entries=4)
    hot = [k for k in idx.fst.keys()][:3]
    for key in hot:
        cache.get_rows(idx, key, 256, 1)
    for i in range(20):  # 20 distinct absent keys
        cache.get_rows(idx, (10**6 + i, 1, 2), 256, 1)
    st0 = cache.stats
    for key in hot:  # all still resident
        cache.get_rows(idx, key, 256, 1)
    st = cache.stats
    assert st["hits"] == st0["hits"] + 3
    assert st["entries"] == 3 and st["negative_entries"] == 4


def test_cache_invalidates_on_refresh():
    table, lex = generate_corpus(n_docs=40, mean_doc_len=50, vocab_size=300, seed=7)
    lex.sw_count = 10
    lex.fu_count = 20
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=16)
    docs = table.to_doc_lists()
    for d in docs[:20]:
        seg.add_document(d)
    v1 = seg.refresh()
    cache = PackedPostingCache()
    key = next(iter(v1.fst.keys()))
    g1, _, _, _ = cache.get_rows(v1, key, 256, 1)
    assert cache.get_rows(v1, key, 256, 1)[0] is g1  # hit
    for d in docs[20:]:
        seg.add_document(d)
    v2 = seg.refresh()
    assert snapshot_token(v2) != snapshot_token(v1)
    g2, _, _, _ = cache.get_rows(v2, key, 256, 1)
    assert cache.stats["invalidations"] == 1
    # the new snapshot has more postings for the key: rows must differ
    assert not np.array_equal(g1, g2)


# -- engine ----------------------------------------------------------------
def test_engine_warm_equals_cold_and_uncached(world):
    table, lex, idx, queries, mesh = world
    eng = SearchServingEngine(idx, mesh, buckets=BUCKETS, max_batch=8, top_k=16)
    plain = SearchServingEngine(
        idx, mesh, buckets=BUCKETS, max_batch=8, top_k=16, use_pack_cache=False
    )
    cold = _drain(eng, queries)
    warm = _drain(eng, queries)
    baseline = _drain(plain, queries)
    assert cold == warm == baseline
    assert eng.stats["pack_cache"]["hits"] > 0
    assert plain.pack_cache is None


@pytest.mark.parametrize("source", ["static", "segmented"])
def test_compressed_engine_matches_uncompressed(world, source):
    table, lex, idx, queries, mesh = world
    if source == "segmented":
        seg = SegmentedIndex(lex, max_distance=D, memtable_docs=16)
        for d in table.to_doc_lists():
            seg.add_document(d)
        seg.refresh()
        index = seg
    else:
        index = idx
    base = SearchServingEngine(index, mesh, buckets=BUCKETS, max_batch=8, top_k=16)
    comp = SearchServingEngine(
        index, mesh, buckets=BUCKETS, max_batch=8, top_k=16, compressed=True
    )
    assert _drain(base, queries) == _drain(comp, queries)
    assert comp.stats["compressed_batches"] > 0


def test_compressed_after_delete_compact_and_refresh(world):
    """Stale-cache regression: serve, mutate (delete + major compaction),
    refresh — both engines must agree and never serve the deleted doc or
    any stale cached rows."""
    table, lex, idx, queries, mesh = world
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=16)
    for d in table.to_doc_lists():
        seg.add_document(d)
    seg.refresh()
    base = SearchServingEngine(seg, mesh, buckets=BUCKETS, max_batch=8, top_k=16)
    comp = SearchServingEngine(
        seg, mesh, buckets=BUCKETS, max_batch=8, top_k=16, compressed=True
    )
    first = _drain(base, queries)
    assert first == _drain(comp, queries)
    victim = None
    for resp in first:
        if resp:
            victim = int(resp[0][0])
            break
    assert victim is not None
    seg.delete_document(victim)
    seg.compact(force=True)
    seg.refresh()
    base.refresh()
    comp.refresh()
    after_base = _drain(base, queries)
    assert after_base == _drain(comp, queries)
    assert after_base != first  # the deletion is visible through the cache
    served = {doc for resp in after_base for doc, _, _, _ in resp}
    assert victim not in served
    assert base.stats["pack_cache"]["invalidations"] >= 1
    # equivalence against a from-scratch engine over the same snapshot:
    # cached rows match a cache that never saw the old snapshot
    fresh = SearchServingEngine(seg, mesh, buckets=BUCKETS, max_batch=8, top_k=16)
    assert after_base == _drain(fresh, queries)


def test_batch_shape_bucketing(world):
    table, lex, idx, queries, mesh = world
    assert [batch_size_bucket(n, 64) for n in (1, 2, 3, 5, 9, 64)] == [
        1, 2, 4, 8, 16, 64]
    assert batch_size_bucket(7, 4) == 4  # capped
    eng = SearchServingEngine(idx, mesh, buckets=(1024,), max_batch=8, top_k=16)
    got = _drain(eng, queries[:3])  # padded to B=4: 3 real + 1 padding slot
    ref = _drain(eng, queries[:3])
    assert got == ref and len(got) == 3


def test_drain_single_pass_grouping(world):
    """All queued requests are served in one pass: per-bucket groups are
    chunked by max_batch, no request is dropped or served twice."""
    table, lex, idx, queries, mesh = world
    eng = SearchServingEngine(idx, mesh, buckets=BUCKETS, max_batch=4, top_k=16)
    many = (queries * 3)[:12]
    for q in many:
        eng.submit(q)
    resp = eng.drain()
    assert len(resp) == 12
    assert eng.stats["requests"] == 12
    assert eng.stats["batches"] >= 3
    assert not eng._queue


# -- cross-snapshot retention ----------------------------------------------
def _disjoint_vocab_index():
    """Segmented index whose doc batches use disjoint stop-lemma sets, so
    an add-only refresh leaves the first batch's keys untouched."""
    from repro.core.lexicon import Lexicon

    sw, fu = 8, 8
    n_lem = sw + fu + 4
    counts = np.arange(n_lem, 0, -1) * 50
    lex = Lexicon.from_rank_counts(
        counts=counts, doc_freqs=np.minimum(counts, 40), n_docs=40,
        sw_count=sw, fu_count=fu,
    )
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=4, tier_fanout=8)
    docs_a = [[0, 1, 2, 0, 1, 2, 0, 1, 2, 0, 1, 2] for _ in range(8)]
    docs_b = [[3, 4, 5, 3, 4, 5, 3, 4, 5, 3, 4, 5] for _ in range(8)]
    return seg, docs_a, docs_b


def test_addonly_refresh_retains_untouched_keys():
    """After an add-only refresh, keys no new segment touches keep their
    cached rows (same arrays, served as hits); keys the new segments do
    touch are re-derived; any delete clears everything."""
    seg, docs_a, docs_b = _disjoint_vocab_index()
    for d in docs_a:
        seg.add_document(d)
    v1 = seg.refresh()
    cache = PackedPostingCache()
    key_a = (0, 1, 2)
    assert key_a in v1.fst
    g1, _, _, _ = cache.get_rows(v1, key_a, 256, 1)
    for d in docs_b:
        seg.add_document(d)
    v2 = seg.refresh()
    st0 = cache.stats
    g2, _, _, _ = cache.get_rows(v2, key_a, 256, 1)
    assert g2 is g1  # retained, not re-derived
    assert cache.stats["hits"] == st0["hits"] + 1
    assert cache.stats["retained"] >= 1
    assert cache.stats["invalidations"] == 1
    # retained rows are bitwise what a fresh derivation would produce
    assert np.array_equal(g2, pack_fst_key_rows(v2, key_a, 256, 1)[0])
    # a key the added segments do touch misses and re-derives
    key_b = (3, 4, 5)
    assert key_b in v2.fst
    misses0 = cache.stats["misses"]
    cache.get_rows(v2, key_b, 256, 1)
    assert cache.stats["misses"] == misses0 + 1
    # a delete is not add-only: the whole cache clears
    seg.delete_document(0)
    v3 = seg.refresh()
    misses1 = cache.stats["misses"]
    g3, _, _, _ = cache.get_rows(v3, key_a, 256, 1)
    assert cache.stats["misses"] == misses1 + 1
    assert g3 is not g1


def test_addonly_retention_drops_touched_entries_only():
    seg, docs_a, docs_b = _disjoint_vocab_index()
    for d in docs_a + docs_b:
        seg.add_document(d)
    v1 = seg.refresh()
    cache = PackedPostingCache()
    for key in ((0, 1, 2), (3, 4, 5)):
        cache.get_rows(v1, key, 256, 1)
    # add more docs touching only the B vocabulary
    for d in docs_b[:4]:
        seg.add_document(d)
    v2 = seg.refresh()
    cache.get_rows(v2, (0, 1, 2), 256, 1)  # hit (retained)
    st = cache.stats
    assert st["hits"] == 1 and st["retained"] >= 1
    cache.get_rows(v2, (3, 4, 5), 256, 1)  # miss (touched by new segs)
    assert cache.stats["misses"] == 3
    # rows for the touched key now reflect the new postings
    g = cache.get_rows(v2, (3, 4, 5), 256, 1)[0]
    assert np.array_equal(g, pack_fst_key_rows(v2, (3, 4, 5), 256, 1)[0])


def test_pure_compaction_retains_all_keys():
    """A compaction with no intervening deletes is invisible to the cache
    (DESIGN.md §18): merge outputs whose ``derived_from`` lineage lies in
    the old snapshot contribute no fresh segments, so *every* warm key is
    retained and served bitwise-identically."""
    seg, docs_a, docs_b = _disjoint_vocab_index()
    for d in docs_a + docs_b:
        seg.add_document(d)
    v1 = seg.refresh()
    assert len(v1.segments) > 1
    cache = PackedPostingCache()
    g_a = cache.get_rows(v1, (0, 1, 2), 256, 1)[0]
    g_b = cache.get_rows(v1, (3, 4, 5), 256, 1)[0]
    seg.compact(force=True)
    v2 = seg.refresh()
    assert len(v2.segments) == 1 and v2.segments[0].derived_from
    assert cache.get_rows(v2, (0, 1, 2), 256, 1)[0] is g_a
    assert cache.get_rows(v2, (3, 4, 5), 256, 1)[0] is g_b
    st = cache.stats
    assert st["retained"] >= 2 and st["hits"] == 2 and st["misses"] == 2
    assert np.array_equal(g_a, pack_fst_key_rows(v2, (0, 1, 2), 256, 1)[0])


def test_compaction_with_new_deletes_clears():
    """A delete between the cached snapshot and the merge makes lineage
    insufficient: the transition clears rather than retain a row whose
    doc set shrank."""
    seg, docs_a, docs_b = _disjoint_vocab_index()
    for d in docs_a + docs_b:
        seg.add_document(d)
    v1 = seg.refresh()
    cache = PackedPostingCache()
    g_a = cache.get_rows(v1, (0, 1, 2), 256, 1)[0]
    seg.delete_document(0)  # doc 0 holds the (0,1,2) vocabulary
    seg.compact(force=True)
    v2 = seg.refresh()
    g2 = cache.get_rows(v2, (0, 1, 2), 256, 1)[0]
    assert g2 is not g_a  # cleared + re-derived, not retained
    assert cache.stats["retained"] == 0
    assert np.array_equal(g2, pack_fst_key_rows(v2, (0, 1, 2), 256, 1)[0])


def test_live_overlay_stales_touched_keys_only():
    """Against a live memtable view, only keys the overlay could
    contribute postings to re-derive; vocabulary the memtable never saw
    stays retained — and the overlay's own rows are never retained into
    the next snapshot."""
    seg, docs_a, docs_b = _disjoint_vocab_index()
    for d in docs_a:
        seg.add_document(d)
    v1 = seg.refresh()
    cache = PackedPostingCache()
    g_a = cache.get_rows(v1, (0, 1, 2), 256, 1)[0]
    for d in docs_b[:3]:  # memtable only (memtable_docs=4): no seal
        seg.add_document(d)
    lv = seg.live_view()
    assert lv.mem_overlay is not None
    # untouched key: retained into the overlay view, same arrays
    assert cache.get_rows(lv, (0, 1, 2), 256, 1)[0] is g_a
    assert cache.stats["retained"] >= 1
    # overlay-touched key: derived against the live view, matching a
    # direct pack over it (memtable docs included)
    g_b_live = cache.get_rows(lv, (3, 4, 5), 256, 1)[0]
    assert np.array_equal(g_b_live, pack_fst_key_rows(lv, (3, 4, 5), 256, 1)[0])
    # sealing the memtable replaces the overlay with a real segment: the
    # overlay-touched entry must not survive into the published snapshot
    v2 = seg.refresh()
    g_b_pub = cache.get_rows(v2, (3, 4, 5), 256, 1)[0]
    assert np.array_equal(g_b_pub, pack_fst_key_rows(v2, (3, 4, 5), 256, 1)[0])
    # the untouched key is still the original arrays across both hops
    assert cache.get_rows(v2, (0, 1, 2), 256, 1)[0] is g_a


# -- compressed-row cache ---------------------------------------------------
def test_compressed_cache_rows_match_batch_encoder(world):
    """Per-key compressed rows must reproduce what the whole-batch
    encoder emits for that key's slice."""
    from repro.core.jax_search import compress_qt1_batch, pack_qt1_batch

    table, lex, idx, queries, mesh = world
    raw = PackedPostingCache()
    ccache = PackedPostingCache(source=raw)
    batch = pack_qt1_batch(idx, queries[:4], L=256, K=2)
    args = compress_qt1_batch(batch, delta_g=True)
    key_base, key_delta, lo_off, hi_off = (np.asarray(a) for a in args[:4])
    from repro.core.query import select_fst_keys

    for qi, q in enumerate(queries[:4]):
        _, keys = select_fst_keys(list(q))
        keys = (keys + [keys[-1]] * 2)[:2]
        for ki, key in enumerate(keys):
            base, delta, lo_o, hi_o, ok, present = ccache.get(idx, "fst_c", key, 256, 1)
            assert ok and present
            assert np.array_equal(base, key_base[qi, ki])
            assert np.array_equal(delta, key_delta[qi, ki])
            assert np.array_equal(lo_o, lo_off[qi, ki])
            assert np.array_equal(hi_o, hi_off[qi, ki])
    assert ccache.stats["bytes"] > 0
    # the compressed cache derived its raw rows through `source`
    assert raw.stats["misses"] > 0


def test_engine_compressed_cache_stats_and_warm_equivalence(world):
    table, lex, idx, queries, mesh = world
    eng = SearchServingEngine(idx, mesh, buckets=BUCKETS, max_batch=8, top_k=16,
                              compressed=True)
    reenc = SearchServingEngine(idx, mesh, buckets=BUCKETS, max_batch=8, top_k=16,
                                compressed=True, use_compressed_cache=False)
    assert eng.compressed_cache is not None and reenc.compressed_cache is None
    cold = _drain(eng, queries)
    warm = _drain(eng, queries)
    assert cold == warm == _drain(reenc, queries)
    st = eng.stats["compressed_cache"]
    assert st["hits"] > 0 and st["misses"] > 0
    assert st["hit_rate"] > 0.4  # second drain is all hits


def test_decode_results_skips_masked_rows():
    stride = 100
    s = np.array([[5.0, 4.0, -1e30], [-1e30] * 3, [7.0, -1e30, -1e30]], np.float32)
    g = np.array([[205, 310, 0], [0] * 3, [499, 0, 0]], np.int32)
    lo = np.array([[203, 309, 0], [0] * 3, [495, 0, 0]], np.int32)
    hi = np.array([[207, 312, 0], [0] * 3, [499, 0, 0]], np.int32)
    batch = QT1Batch(None, None, None, None, None, stride)
    out = decode_results(batch, s, g, lo, hi)
    assert [o["doc"].tolist() for o in out] == [[2, 3], [], [4]]
    assert out[0]["start"].tolist() == [3, 9]
    assert out[0]["end"].tolist() == [7, 12]
    assert out[2]["score"].tolist() == [7.0]
    assert out[1]["doc"].size == 0
