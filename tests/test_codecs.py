import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.codecs import (
    delta_decode,
    delta_encode,
    varbyte_decode,
    varbyte_encode,
    zigzag_decode,
    zigzag_encode,
)


@given(st.lists(st.integers(min_value=0, max_value=2**63 - 1), max_size=200))
@settings(max_examples=100, deadline=None)
def test_varbyte_roundtrip(values):
    arr = np.array(values, np.uint64)
    assert np.array_equal(varbyte_decode(varbyte_encode(arr)), arr)


@given(st.lists(st.integers(min_value=-(2**62), max_value=2**62), max_size=200))
@settings(max_examples=100, deadline=None)
def test_zigzag_roundtrip(values):
    arr = np.array(values, np.int64)
    assert np.array_equal(zigzag_decode(zigzag_encode(arr)), arr)


@given(st.lists(st.integers(min_value=0, max_value=2**40), min_size=1, max_size=200))
@settings(max_examples=100, deadline=None)
def test_delta_roundtrip_sorted(values):
    arr = np.sort(np.array(values, np.int64))
    assert np.array_equal(delta_decode(delta_encode(arr)), arr)


def test_varbyte_empty():
    assert varbyte_encode(np.zeros(0, np.uint64)) == b""
    assert varbyte_decode(b"").size == 0


def test_varbyte_compression_small_values():
    arr = np.arange(100, dtype=np.uint64)
    assert len(varbyte_encode(arr)) == 100  # 1 byte each


def test_postings_roundtrip():
    from repro.core.postings import decode_postings, encode_postings

    rng = np.random.default_rng(0)
    n = 500
    docs = np.sort(rng.integers(0, 50, n))
    pos = rng.integers(0, 1000, n)
    # positions sorted within doc runs
    order = np.lexsort((pos, docs))
    docs, pos = docs[order].astype(np.int64), pos[order].astype(np.int64)
    extra = rng.integers(0, 20, n).astype(np.int64)
    blob = encode_postings([docs, pos, extra.astype(np.uint64)])
    d2, p2, e2 = decode_postings(blob, 3)
    assert np.array_equal(d2, docs)
    assert np.array_equal(p2, pos)
    assert np.array_equal(e2, extra)


def test_nsw_roundtrip():
    from repro.core.nsw import decode_nsw_stream, encode_nsw_stream

    rng = np.random.default_rng(1)
    n_records = 40
    e = 120
    rows = np.sort(rng.integers(0, n_records, e))
    fls = rng.integers(0, 700, e)
    offs = rng.integers(-5, 6, e)
    offs[offs == 0] = 1
    blob = encode_nsw_stream(rows, fls, offs, n_records)
    r2, f2, o2 = decode_nsw_stream(blob, n_records)
    # same multiset per record
    a = sorted(zip(rows.tolist(), fls.tolist(), offs.tolist()))
    b = sorted(zip(r2.tolist(), f2.tolist(), o2.tolist()))
    assert a == b
