"""Deadline control loop (DESIGN.md §17): admission verdicts, overload
hysteresis, degraded routing, EDF group splitting, bounded-queue
shedding, and the never-hangs contract for rejected/shed tickets.

The service-level tests pin predictions by construction instead of by
measurement: a never-drained service has no ``serve.batch.*`` / compile
samples, so :class:`StepCostPredictor` falls back to the *unit*
estimate (``unit_us_per_kslot`` × slots, zero compile penalty) — fully
deterministic, and linear in both B and the L-bucket, which is exactly
the lever the scenarios below steer with."""

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.data.corpus import generate_corpus, sample_typed_queries
from repro.launch.mesh import make_mesh
from repro.serving import SearchService, ServeConfig
from repro.serving.admission import (
    ADMIT,
    DEGRADE,
    MARGIN_MIN_SAMPLES,
    MARGIN_SAFETY,
    REASON_NO_BUDGET,
    REASON_OPTIMISTIC,
    REJECT_INFEASIBLE,
    SHED_OVERLOAD,
    STATUS_DEGRADED,
    STATUS_REJECTED,
    STATUS_SHED,
    AdmissionController,
)
from repro.serving.costs import RecallCostModel

D = 5
BUCKETS = (64, 256, 1024)


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500,
                                 seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    mesh = make_mesh((1, 1), ("data", "model"))
    queries = (sample_typed_queries(table, lex, 10, "qt5", window=D, seed=3)
               + sample_typed_queries(table, lex, 10, "qt3", window=D, seed=4))
    return idx, mesh, queries


def _service(idx, mesh, **over):
    # top_k must not exceed the smallest bucket (the top-k minor dim)
    over = {"buckets": BUCKETS, "max_batch": 8, "top_k": BUCKETS[0], **over}
    return SearchService(idx, mesh, ServeConfig(**over))


def _compiled_query(svc, queries):
    for q in queries:
        if svc.explain(q).is_compiled:
            return q
    pytest.skip("no compiled-route query in the sample")


def _result_set(resp):
    return set(zip(resp.results["doc"].tolist(),
                   resp.results["start"].tolist(),
                   resp.results["end"].tolist()))


# -- 1. infeasible budgets are rejected fast, at submit --------------------
def test_infeasible_fast_reject(world):
    idx, mesh, queries = world
    # unit cost so large every compiled/degraded candidate dwarfs any
    # millisecond budget; the scalar backstop is not in the candidate
    # set for a compiled plan
    svc = _service(idx, mesh, admission=True, unit_us_per_kslot=1e9)
    q = _compiled_query(svc, queries)
    t = svc.submit(q, deadline_s=0.01)
    # resolved at submit: no drain ran, result() does not raise/hang
    assert t.done
    resp = t.result()
    assert resp.status == STATUS_REJECTED
    assert t.verdict.decision == REJECT_INFEASIBLE
    assert resp.deadline_met is False
    assert resp.deadline_blame == "infeasible"
    assert resp.results["doc"].size == 0
    st = svc.stats_snapshot()
    assert st["admission"]["rejected_infeasible"] == 1
    assert st["deadlines"]["miss_blame"] == {"infeasible": 1}
    # the rejected ticket is not queued: drain serves nothing
    assert svc.drain() == []


def test_no_budget_requests_always_admit(world):
    idx, mesh, queries = world
    svc = _service(idx, mesh, admission=True, unit_us_per_kslot=1e9)
    q = _compiled_query(svc, queries)
    t = svc.submit(q)  # no deadline: nothing to enforce
    assert not t.done
    assert t.verdict.decision == ADMIT
    assert t.verdict.reason == REASON_NO_BUDGET
    (resp,) = svc.drain()
    assert resp.status == "ok"
    assert t.result() is resp


# -- 2. overload hysteresis: latch, no flap in the dead band ---------------
def test_hysteresis_latch_under_burst():
    # alpha=1 -> the EWMA is the raw backlog, so the latch thresholds
    # are exercised directly; optimism is huge so shedding can only
    # come from the latch
    ctrl = AdmissionController(enter_s=0.1, exit_s=0.025, margin=1.0,
                               optimism=1e9, alpha=1.0)
    cand = [(None, 0.01)]

    # marginal predicted miss, unlatched -> optimistic admit
    v = ctrl.consider(cand, backlog_s=0.05, budget_s=0.04)
    assert v.decision == ADMIT and v.reason == REASON_OPTIMISTIC
    assert not ctrl.overloaded and ctrl.transitions == 0

    # burst pushes the backlog past enter_s -> latch + shed
    v = ctrl.consider(cand, backlog_s=0.2, budget_s=0.04)
    assert v.decision == SHED_OVERLOAD
    assert ctrl.overloaded and ctrl.transitions == 1

    # dead band (exit < backlog < enter): still latched, still shedding
    v = ctrl.consider(cand, backlog_s=0.05, budget_s=0.04)
    assert v.decision == SHED_OVERLOAD
    assert ctrl.overloaded and ctrl.transitions == 1

    # backlog collapses below exit_s -> unlatch; with no backlog the
    # request is simply predicted to meet
    v = ctrl.consider(cand, backlog_s=0.0, budget_s=0.04)
    assert v.decision == ADMIT and v.reason == "predicted_met"
    assert not ctrl.overloaded and ctrl.transitions == 2


def test_ewma_smooths_drain_sawtooth():
    # the drain loop empties the queue every cycle: the raw backlog hits
    # zero between drains, and an unsmoothed latch would flap on it
    ctrl = AdmissionController(enter_s=0.1, exit_s=0.025, margin=1.0,
                               optimism=1e9, alpha=0.3)
    for _ in range(20):
        ctrl._update_overload(0.2)
    assert ctrl.overloaded and ctrl.transitions == 1
    ctrl._update_overload(0.0)  # one drain-boundary zero sample
    assert ctrl.overloaded, "a single zero backlog must not unlatch"
    assert ctrl.transitions == 1


def test_hysteresis_rejects_inverted_thresholds():
    with pytest.raises(ValueError):
        AdmissionController(enter_s=0.01, exit_s=0.05)


# -- 3. degraded routing: cheaper bucket, results a subset of full ---------
def test_degraded_route_results_subset_of_full(world):
    idx, mesh, queries = world
    full = _service(idx, mesh)
    # unit 1e6 us/kslot: a B=1 batch costs ~0.1-1s per bucket step, so a
    # budget between the degraded and planned bucket costs is wide open
    # against planning overhead (ms)
    svc = _service(idx, mesh, admission=True, unit_us_per_kslot=1e6,
                   admit_margin=1.0, admit_optimism=1.0)
    for q in queries:
        p = svc.explain(q)
        if p.is_compiled and p.bucket > BUCKETS[0] and svc.drain() == []:
            fr = full.submit(q)
            full.drain()
            if _result_set(fr.result()):
                break
    else:
        pytest.skip("no compiled query above the smallest bucket")
    b_deg = max(b for b in BUCKETS if b < p.bucket)
    cost_deg = svc.predictor.batch_s(p.step_family, 1, b_deg)
    cost_full = svc.predictor.batch_s(p.step_family, 1, p.bucket)
    deadline = 2.0 * cost_deg + 0.05
    assert deadline < cost_full, "scenario needs a budget only degrade fits"

    t = svc.submit(q, deadline_s=deadline)
    assert t.verdict.decision == DEGRADE
    assert t.verdict.bucket == b_deg
    (resp,) = svc.drain()
    assert resp.status == STATUS_DEGRADED
    assert resp.plan.degraded and resp.plan.bucket == b_deg
    # a truncated posting prefix can only lose matches, never invent them
    assert _result_set(resp) <= _result_set(fr.result())
    assert svc.stats_snapshot()["admission"]["degraded"] == 1


# -- 4. EDF group splitting is a scheduling move, not a results change -----
def test_edf_split_results_bit_identical(world):
    idx, mesh, queries = world
    svc = _service(idx, mesh, max_batch=4, split_budget=2)
    ref = _service(idx, mesh, max_batch=4, split_budget=0)
    qs = [q for q in queries if svc.explain(q).is_compiled][:6]
    if len(qs) < 3:
        pytest.skip("not enough compiled queries to form a split group")
    # deterministic split trigger: predictions grow linearly in B, so a
    # tight-deadline tail always prefers the small urgent sub-batch
    # (strict_warm handled by the stub — no cold-shape refusal)
    svc.predictor.batch_s = lambda family, B, bucket, strict_warm=False: float(B)
    tickets = [svc.submit(q, deadline_s=0.001 if i < 2 else None)
               for i, q in enumerate(qs)]
    got = svc.drain()
    split_metric = svc.metrics_snapshot("serve.admission.split")
    assert split_metric["serve.admission.split"] >= 1, "split did not trigger"

    for q in qs:
        ref.submit(q)
    want = ref.drain()
    assert len(got) == len(want) == len(qs)
    for t, g, w in zip(tickets, got, want):
        assert t.result() is g
        for key in g.results:
            assert np.array_equal(g.results[key], w.results[key]), key


# -- 5. bounded queue sheds the infeasible waiter, never the feasible ------
def test_queue_shed_drops_infeasible_not_feasible(world):
    idx, mesh, queries = world
    # optimism huge + latch thresholds out of reach: predicted misses
    # all admit at the admission step, so overflow pressure lands on
    # the bounded queue; degrade off keeps every ticket in its planned
    # group
    svc = _service(idx, mesh, admission=True, max_batch=2, max_queue=3,
                   unit_us_per_kslot=1e6, admit_margin=1.0,
                   admit_optimism=1e9, degrade=False,
                   shed_enter_s=1e9, shed_exit_s=0.0)
    q = _compiled_query(svc, queries)
    p = svc.explain(q)
    # group cost per B=2 batch; 3 queued same-group tickets = 2 batches
    c2 = svc.predictor.batch_s(p.step_family, 2, p.bucket)

    t1 = svc.submit(q, deadline_s=100.0)            # FIFO head, feasible
    t2 = svc.submit(q, deadline_s=100.0)
    t3 = svc.submit(q, deadline_s=1.5 * c2)         # backlog outruns this
    t4 = svc.submit(q, deadline_s=100.0)            # overflow trigger
    assert not t1.done and not t2.done and not t4.done
    assert t3.done, "the infeasible waiter is the victim"
    assert t3.result().status == STATUS_SHED
    assert t3.result().deadline_blame == "shed"
    st = svc.stats_snapshot()
    assert st["admission"]["queue_shed"] == 1
    assert len(svc.drain()) == 3  # t1, t2, t4 all served


def test_queue_shed_newcomer_when_all_feasible(world):
    idx, mesh, queries = world
    svc = _service(idx, mesh, admission=True, max_batch=2, max_queue=2,
                   unit_us_per_kslot=1e6, admit_margin=1.0,
                   admit_optimism=1e9, degrade=False,
                   shed_enter_s=1e9, shed_exit_s=0.0)
    q = _compiled_query(svc, queries)
    t1 = svc.submit(q, deadline_s=100.0)
    t2 = svc.submit(q, deadline_s=100.0)
    t3 = svc.submit(q, deadline_s=100.0)  # overflow, everyone feasible
    assert not t1.done and not t2.done
    assert t3.done and t3.result().status == STATUS_SHED
    assert len(svc.drain()) == 2


# -- 6. rejected/shed tickets resolve like responses, never hang -----------
def test_unserved_tickets_resolve_with_full_contract(world):
    idx, mesh, queries = world
    svc = _service(idx, mesh, admission=True, unit_us_per_kslot=1e9)
    q = _compiled_query(svc, queries)
    t = svc.submit(q, deadline_s=0.005)
    resp = t.result()  # no drain needed
    assert resp.status == STATUS_REJECTED
    assert resp.results["doc"].size == 0
    assert resp.deadline_met is False
    assert resp.queue_wait_s >= 0.0
    assert resp.phases["queue"] == resp.queue_wait_s
    assert resp.plan is not None and resp.plan.is_compiled
    # deadline accounting: an unserved deadline'd request is a miss
    dl = svc.stats_snapshot()["deadlines"]
    assert dl["missed"] == 1 and dl["met"] == 0


# -- 7. adaptive admission reserve (DESIGN.md §19) -------------------------
def _ctl(**over):
    kw = {"margin": 0.4, **over}
    return AdmissionController(0.1, 0.025, **kw)


def test_adaptive_margin_rises_on_accurate_predictions():
    ctl = _ctl()
    assert ctl.margin == 0.4
    for _ in range(2 * MARGIN_MIN_SAMPLES):
        ctl.observe_completion(0.010, 0.010)
    # realized error ~1.0 -> reserve relaxes to 1/safety, above static
    assert ctl.margin == pytest.approx(1.0 / MARGIN_SAFETY)
    assert ctl.margin > ctl.static_margin


def test_adaptive_margin_floors_at_static_when_predictions_lowball():
    ctl = _ctl()
    for _ in range(2 * MARGIN_MIN_SAMPLES):
        ctl.observe_completion(0.010, 0.030)  # actual 3x the prediction
    # derived margin 1/(3*safety) < static -> static stays the floor
    assert ctl.margin == ctl.static_margin == 0.4


def test_adaptive_margin_waits_for_min_samples():
    ctl = _ctl()
    for _ in range(MARGIN_MIN_SAMPLES - 1):
        ctl.observe_completion(0.010, 0.010)
    assert ctl.margin == ctl.static_margin


def test_adaptive_margin_disabled_stays_static():
    ctl = _ctl(adaptive_margin=False)
    for _ in range(4 * MARGIN_MIN_SAMPLES):
        ctl.observe_completion(0.010, 0.010)
    assert ctl.margin == ctl.static_margin
    assert ctl.margin_stats()["adaptive"] == 0


def test_margin_stats_report_realized_error():
    ctl = _ctl()
    stats = ctl.margin_stats()
    assert stats["n_samples"] == 0 and stats["error_p50"] is None
    for _ in range(2 * MARGIN_MIN_SAMPLES):
        ctl.observe_completion(0.010, 0.020)
    stats = ctl.margin_stats()
    assert stats["error_p50"] == pytest.approx(2.0)
    assert stats["error_p95"] == pytest.approx(2.0)
    assert stats["static"] == 0.4
    assert stats["effective"] == ctl.margin
    # degenerate observations are ignored, not divided by
    ctl.observe_completion(0.0, 0.010)
    ctl.observe_completion(0.010, -1.0)
    assert ctl.margin_stats()["n_samples"] == stats["n_samples"]


# -- 8. recall-cost degrade ordering (DESIGN.md §19) -----------------------
def test_recall_model_cold_order_is_largest_first():
    rc = RecallCostModel()
    assert rc.order("qt5", [64, 1024, 256], 4096) == [1024, 256, 64]
    assert rc.recall("qt5", 256) is None


def test_recall_model_warm_order_prefers_measured_recall():
    rc = RecallCostModel(min_samples=2)
    for _ in range(3):
        rc.observe_full("qt5", 100)
        rc.observe_degraded("qt5", 64, 90)    # tiny prefix, high recall
        rc.observe_degraded("qt5", 1024, 30)  # big prefix, low recall
    assert rc.recall("qt5", 64) == pytest.approx(0.9)
    # 256 unmeasured -> prefix prior 256/4096; measured recalls win
    assert rc.order("qt5", [64, 1024, 256], 4096) == [64, 1024, 256]
    table = rc.table()
    assert table["qt5/L64"]["recall"] == pytest.approx(0.9)
    assert table["qt5/full"]["n"] == 3


def test_recall_model_clamps_and_undersamples():
    rc = RecallCostModel(min_samples=2)
    for _ in range(2):
        rc.observe_full("qt3", 10)
        rc.observe_degraded("qt3", 64, 25)  # noisy count above the full
    assert rc.recall("qt3", 64) == 1.0  # clamped: recall cannot exceed 1
    rc.observe_degraded("qt3", 256, 5)
    assert rc.recall("qt3", 256) is None  # one sample is not evidence


def test_service_degrade_picks_highest_measured_recall(world):
    idx, mesh, queries = world
    svc = _service(idx, mesh, buckets=(16, 64, 256), top_k=16,
                   admission=True, unit_us_per_kslot=1e6,
                   admit_margin=1.0, admit_optimism=1.0)
    for q in queries:
        p = svc.explain(q)
        if p.is_compiled and p.bucket == 256:
            break
    else:
        pytest.skip("no compiled query planned at the top bucket")
    # rig measured recalls so the SMALLEST prefix retains the most
    # results — the opposite of the prefix-fraction prior
    for _ in range(svc.recall_costs.min_samples):
        svc.recall_costs.observe_full(p.step_family, 100)
        svc.recall_costs.observe_degraded(p.step_family, 16, 95)
        svc.recall_costs.observe_degraded(p.step_family, 64, 20)
    cost_64 = svc.predictor.batch_s(p.step_family, 1, 64)
    cost_full = svc.predictor.batch_s(p.step_family, 1, p.bucket)
    deadline = 2.0 * cost_64 + 0.05
    assert deadline < cost_full, "scenario needs a degrade-only budget"
    t = svc.submit(q, deadline_s=deadline)
    # the old largest-first policy would pick 64; measured recall says 16
    assert t.verdict.decision == DEGRADE
    assert t.verdict.bucket == 16
    (resp,) = svc.drain()
    assert resp.plan.degraded and resp.plan.bucket == 16


def test_service_snapshot_exposes_margin_and_recall(world):
    idx, mesh, queries = world
    svc = _service(idx, mesh, admission=True)
    q = _compiled_query(svc, queries)
    svc.submit(q, deadline_s=30.0)
    svc.drain()
    adm = svc.stats_snapshot()["admission"]
    # served admits feed the realized-error window...
    assert adm["margin"]["n_samples"] >= 1
    assert adm["margin"]["static"] == ServeConfig().admit_margin
    assert adm["margin"]["error_p50"] > 0.0
    # ...and full-route completions feed the recall denominators
    full_keys = [k for k in adm["recall"] if k.endswith("/full")]
    assert full_keys, adm["recall"]
