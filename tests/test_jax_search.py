"""Device (batched/sharded) QT1 engine vs the reference CPU engine."""

import numpy as np
import jax
import pytest

from repro.core.index_builder import build_index
from repro.core.jax_search import (
    decode_results,
    make_qt1_serve_step,
    pack_qt1_batch,
    qt1_join,
    qt1_score,
)
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries

D = 5


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    queries = sample_stop_queries(table, lex, 16, window=D, seed=4)
    return table, lex, idx, queries


def _engine_results(idx, q):
    eng = ProximitySearchEngine(idx, top_k=100_000, equalize_mode="bulk")
    res, _ = eng.search_ids(q)
    return set(zip(res.doc.tolist(), res.start.tolist(), res.end.tolist()))


def test_device_qt1_matches_reference(world):
    table, lex, idx, queries = world
    batch = pack_qt1_batch(idx, queries, L=2048, K=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_qt1_serve_step(mesh, top_k=512)
    outs = step(*batch.device_args())
    decoded = decode_results(batch, *outs)
    for qi, q in enumerate(queries):
        got = set(
            zip(
                decoded[qi]["doc"].tolist(),
                decoded[qi]["start"].tolist(),
                decoded[qi]["end"].tolist(),
            )
        )
        want = _engine_results(idx, q)
        assert got == want, (qi, q, got ^ want)


def test_device_qt1_scores_match_reference(world):
    table, lex, idx, queries = world
    batch = pack_qt1_batch(idx, queries, L=2048, K=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_qt1_serve_step(mesh, top_k=64)
    outs = step(*batch.device_args())
    decoded = decode_results(batch, *outs)
    eng = ProximitySearchEngine(idx, top_k=64, equalize_mode="bulk")
    for qi, q in enumerate(queries):
        res, _ = eng.search_ids(q)
        if res.size == 0:
            assert decoded[qi]["doc"].size == 0
            continue
        assert decoded[qi]["score"].size > 0
        np.testing.assert_allclose(
            np.max(decoded[qi]["score"]), float(res.score[0]), rtol=1e-6
        )


def test_doc_sharded_serving_multidevice():
    """The real distributed invariant: on a (2, 4) mesh with doc_shards ==
    model size == 4, the sharded join must match the single-device result.
    Runs in a subprocess with 8 forced host devices."""
    import subprocess
    import sys
    from pathlib import Path

    script = Path(__file__).parent / "multidevice" / "check_sharded_search.py"
    env = dict(
        PYTHONPATH=str(Path(__file__).parent.parent / "src"),
        PATH="/usr/bin:/bin",
        HOME="/root",
    )
    proc = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True, env=env, timeout=300
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_SEARCH_OK" in proc.stdout


def test_qt1_join_handles_all_sentinel_query():
    from repro.kernels.common import SENTINEL

    B, K, L = 2, 2, 64
    g = np.full((B, K, L), SENTINEL, np.int32)
    lo = g.copy()
    hi = g.copy()
    valid, _, _ = qt1_join(*(map(np.asarray, (g, lo, hi))))
    assert not bool(np.asarray(valid).any())
