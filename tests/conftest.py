import os
import sys

# Tests must see exactly 1 CPU device (the dry-run sets 512 in its own
# process); make imports work without installing the package.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
