"""Property tests for the paper §2.3 binary heaps."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.heaps import IteratorHeap


class FakeIter:
    __slots__ = ("value_id", "min_index", "max_index")

    def __init__(self, v):
        self.value_id = v
        self.min_index = 0
        self.max_index = 0


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=50))
@settings(max_examples=100, deadline=None)
def test_insert_maintains_invariant_and_min(values):
    h = IteratorHeap(len(values), "min")
    g = IteratorHeap(len(values), "max")
    its = [FakeIter(v) for v in values]
    for it in its:
        h.insert(it)
        g.insert(it)
        assert h.check_invariant()
        assert g.check_invariant()
    assert h.get_min().value_id == min(values)
    assert g.get_min().value_id == max(values)


@given(
    st.lists(st.integers(0, 100), min_size=2, max_size=20),
    st.lists(st.tuples(st.integers(0, 19), st.integers(1, 50)), max_size=40),
)
@settings(max_examples=100, deadline=None)
def test_update_after_value_changes(values, updates):
    """Simulates IT.next(): bump an iterator's doc id, call Update on both
    heaps via the back-pointer fields, check invariants + extrema."""
    its = [FakeIter(v) for v in values]
    h = IteratorHeap(len(values), "min")
    g = IteratorHeap(len(values), "max")
    for it in its:
        h.insert(it)
        g.insert(it)
    for idx, delta in updates:
        it = its[idx % len(its)]
        it.value_id += delta  # iterators only move forward
        h.update(it.min_index)
        g.update(it.max_index)
        assert h.check_invariant(), "MinHeap invariant broken"
        assert g.check_invariant(), "MaxHeap invariant broken"
        cur = [x.value_id for x in its]
        assert h.get_min().value_id == min(cur)
        assert g.get_min().value_id == max(cur)


def test_paper_example_three_iterators():
    """Fig. 4: IT1.ID=3, IT2.ID=10, IT3.ID=5."""
    it1, it2, it3 = FakeIter(3), FakeIter(10), FakeIter(5)
    mn, mx = IteratorHeap(3, "min"), IteratorHeap(3, "max")
    for it in (it1, it2, it3):
        mn.insert(it)
        mx.insert(it)
    assert mn.get_min() is it1  # first cell of MinHeap array
    assert mx.get_min() is it2  # first cell of MaxHeap array
    assert it1.min_index == 1
    assert it2.max_index == 1
