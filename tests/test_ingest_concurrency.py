"""Concurrency stress for the real-time ingest tier (DESIGN.md §18).

Background merges race ``submit()``/``drain()`` from multiple serving
threads while writer threads add/delete/refresh; fault-injection makes
merges raise mid-flight or stall. The invariants under all of it: no
torn snapshots (every pinned view is internally consistent and
oracle-equivalent), no lost tombstones (a deleted doc is never served
again once its delete is visible), ``CompactionJob.result()`` never
hangs, and the pack cache retains entries across a pure background
merge (``stats["retained"] > 0``) while never serving a stale row.
"""

import threading

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import TokenTable, generate_corpus
from repro.index import CompactionExecutor, SegmentedIndex
from repro.serving.pack_cache import PackedPostingCache

D = 5


@pytest.fixture(scope="module")
def corpus():
    table, lex = generate_corpus(n_docs=150, mean_doc_len=60, vocab_size=400, seed=5)
    lex.sw_count = 12
    lex.fu_count = 25
    return table.to_doc_lists(), lex


def _records(matches, remap=None):
    docs = matches.doc.tolist()
    if remap is not None:
        docs = [remap[int(x)] for x in docs]
    return sorted(
        zip(docs, matches.start.tolist(), matches.end.tolist(),
            np.round(matches.score, 9).tolist())
    )


def _assert_view_equiv(view, docs, lex, queries):
    live = view.live_doc_ids()
    if live.size == 0:
        return
    ftable = TokenTable.from_docs([np.array(docs[int(g)], np.int32) for g in live])
    ref = build_index(ftable, lex, max_distance=D)
    remap = {int(g): i for i, g in enumerate(live.tolist())}
    e_view = ProximitySearchEngine(view, top_k=100_000)
    e_ref = ProximitySearchEngine(ref, top_k=100_000)
    for q in queries:
        r_ref, _ = e_ref.search_ids(q)
        r_view, _ = e_view.search_ids(q)
        assert _records(r_ref) == _records(r_view, remap), q
    return True


def test_readers_race_writer_and_merges(corpus):
    """Serving threads pin snapshots/live views and search while a writer
    adds/deletes/refreshes and background merges swap segments in. Every
    pinned view must be internally consistent (all four structures agree
    with a fresh rebuild of *that view's* doc set) — a torn swap could
    not stay consistent."""
    docs, lex = corpus
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=10, tier_fanout=3, background=True
    )
    errors: list = []
    stop = threading.Event()
    queries = [[0, 1, 2], [0, 1], [1, 2, 3]]

    def reader(k):
        rng = np.random.default_rng(k)
        try:
            while not stop.is_set():
                view = seg.live_view() if rng.integers(2) else seg.snapshot()
                # cheap internal-consistency probe on every lap: merged
                # ordinary reads are sorted and tombstone-free
                live = set(view.live_doc_ids().tolist())
                for q in queries:
                    eng = ProximitySearchEngine(view, top_k=100_000)
                    m, _ = eng.search_ids(q)
                    got = set(int(x) for x in m.doc)
                    assert got <= live, "served a dead or unknown doc"
        except BaseException as exc:  # surfaces in the main thread
            errors.append(exc)

    readers = [threading.Thread(target=reader, args=(k,)) for k in range(3)]
    for t in readers:
        t.start()
    deleted = []
    try:
        rng = np.random.default_rng(42)
        for i, d in enumerate(docs):
            gid = seg.add_document(d)
            if rng.integers(4) == 0:
                seg.delete_document(gid)
                deleted.append(gid)
            if i % 25 == 24:
                seg.refresh(wait=False)
        view = seg.refresh(wait=True)
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=30)
        seg.close()
    assert not errors, errors[0]
    assert seg.stats["merges"] >= 1
    want_live = set(range(len(docs))) - set(deleted)
    assert set(view.live_doc_ids().tolist()) == want_live
    _assert_view_equiv(view, docs, lex, queries)


def test_no_lost_tombstones_under_concurrent_deletes(corpus):
    """Deletes issued from several threads while merges run: every delete
    must hold in the final quiesced view (no resurrection through a
    merge that raced the tombstone)."""
    docs, lex = corpus
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=10, tier_fanout=3, background=True
    )
    try:
        for d in docs:
            seg.add_document(d)
        seg.refresh(wait=False)
        dead = list(range(0, len(docs), 3))
        errors: list = []

        def deleter(ids):
            try:
                for g in ids:
                    seg.delete_document(g)
            except BaseException as exc:
                errors.append(exc)

        threads = [
            threading.Thread(target=deleter, args=(dead[k::4],)) for k in range(4)
        ]
        for t in threads:
            t.start()
        seg.refresh(wait=False)  # merges race the deleters
        for t in threads:
            t.join(timeout=30)
        assert not errors, errors[0]
        view = seg.refresh(wait=True)
        assert set(view.live_doc_ids().tolist()) == set(range(len(docs))) - set(dead)
        _assert_view_equiv(view, docs, lex, [[0, 1, 2], [1, 2]])
    finally:
        seg.close()


def test_merge_failure_leaves_state_intact_and_result_raises(corpus):
    """A merge raising mid-flight must fail its job (result() re-raises,
    never hangs), leave the pre-merge state serving correctly, and let a
    later healthy refresh compact as usual."""
    docs, lex = corpus

    class Boom(RuntimeError):
        pass

    armed = {"on": True}

    def hook(stage, job):
        if stage == "before_swap" and armed["on"]:
            raise Boom("injected mid-merge failure")

    ex = CompactionExecutor(fault_hook=hook)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=100, tier_fanout=3,
        background=True, executor=ex,
    )
    try:
        for i, d in enumerate(docs[:40], 1):
            seg.add_document(d)
            if i % 8 == 0:
                with seg._lock:
                    seg._seal_only()
        n0 = seg.n_segments
        jobs = ex.schedule(seg)
        assert jobs
        with pytest.raises(Boom):
            jobs[0].result(timeout=30)
        assert ex.stats["failed"] == 1
        assert seg.n_segments == n0  # no partial swap
        assert seg.stats["merges"] == 0
        _assert_view_equiv(seg.refresh(wait=False), docs, lex, [[0, 1, 2]])
        armed["on"] = False  # heal the fault: compaction proceeds
        view = seg.refresh(wait=True)
        assert seg.stats["merges"] >= 1
        _assert_view_equiv(view, docs, lex, [[0, 1, 2]])
    finally:
        ex.close()


def test_failed_merge_does_not_wedge_refresh_wait(corpus):
    """refresh(wait=True) over a *persistently* failing executor must
    return (degrade to 'compaction behind'), not spin or deadlock."""
    docs, lex = corpus

    def hook(stage, job):
        if stage == "before_merge":
            raise RuntimeError("always failing")

    ex = CompactionExecutor(fault_hook=hook)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=8, tier_fanout=3,
        background=True, executor=ex,
    )
    try:
        for d in docs[:40]:
            seg.add_document(d)
        view = seg.refresh(wait=True)  # must terminate despite failures
        assert seg.stats["merges"] == 0 and ex.stats["failed"] >= 1
        assert sorted(view.live_doc_ids().tolist()) == list(range(40))
    finally:
        ex.close()


def test_pack_cache_retained_across_background_merge(corpus):
    """Warm pack-cache entries survive a pure background compaction:
    untouched keys are served as hits (stats['retained'] > 0) and the
    retained rows are bitwise what a fresh derivation would produce."""
    from repro.core.jax_search import pack_ord_key_rows

    docs, lex = corpus
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=10, tier_fanout=3, background=True
    )
    try:
        for d in docs[:50]:
            seg.add_document(d)
        v1 = seg.refresh(wait=False)
        cache = PackedPostingCache()
        keys = [0, 1, 2, 13, 14]
        warm = {k: cache.get(v1, "ord", k, 1024, 1) for k in keys}
        v2 = seg.refresh(wait=True)  # quiesce: background merges swapped in
        assert seg.stats["merges"] >= 1
        st0 = cache.stats
        for k in keys:
            got = cache.get(v2, "ord", k, 1024, 1)
            assert got[0] is warm[k][0]  # retained: same arrays, no re-derivation
            assert np.array_equal(got[0], pack_ord_key_rows(v2, k, 1024, 1)[0])
        st = cache.stats
        assert st["retained"] > 0
        assert st["hits"] == st0["hits"] + len(keys)
        assert st["misses"] == st0["misses"]
    finally:
        seg.close()


def test_wait_idle_and_result_timeouts_bounded(corpus):
    """wait_idle(timeout) returns False (not hangs) while a merge stalls,
    and result(timeout) raises TimeoutError — then both complete once the
    stall lifts."""
    docs, lex = corpus
    hold, entered = threading.Event(), threading.Event()

    def hook(stage, job):
        if stage == "before_merge":
            entered.set()
            assert hold.wait(30)

    ex = CompactionExecutor(fault_hook=hook)
    seg = SegmentedIndex(
        lex, max_distance=D, memtable_docs=100, tier_fanout=3,
        background=True, executor=ex,
    )
    try:
        for i, d in enumerate(docs[:40], 1):
            seg.add_document(d)
            if i % 8 == 0:
                with seg._lock:
                    seg._seal_only()
        jobs = ex.schedule(seg)
        assert jobs and entered.wait(30)
        assert ex.wait_idle(timeout=0.2) is False
        with pytest.raises(TimeoutError):
            jobs[0].result(timeout=0.2)
        hold.set()
        assert jobs[0].result(timeout=30) in ("merged", "noop")
        assert ex.wait_idle(30)
    finally:
        hold.set()
        ex.close()
