"""End-to-end system behaviour tests: the full paper pipeline on both the
reference engine and the device engine, plus the experiment harness."""

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.search import InvertedIndexEngine, ProximitySearchEngine
from repro.data.corpus import generate_corpus, sample_stop_queries


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=300, mean_doc_len=100, vocab_size=5000, seed=42)
    return table, lex


def test_end_to_end_maxdistance_dependence(world):
    """Paper §3.2: postings/bytes per query grow with MaxDistance but stay
    orders of magnitude below the inverted-file baseline."""
    table, lex = world
    queries = sample_stop_queries(table, lex, 30, window=3, seed=0)

    idx1 = build_index(table, lex, 5, build_wv=False, build_fst=False, build_nsw=False)
    base = InvertedIndexEngine(idx1, top_k=50)
    base_postings = base_bytes = 0
    for q in queries:
        _, s = base.search_ids(q)
        base_postings += s.postings
        base_bytes += s.bytes_read

    prev_bytes = 0
    for d in (5, 7, 9):
        idx = build_index(table, lex, d)
        eng = ProximitySearchEngine(idx, top_k=50)
        tot_p = tot_b = 0
        for q in queries:
            _, s = eng.search_ids(q)
            tot_p += s.postings
            tot_b += s.bytes_read
        assert tot_p < base_postings / 3, f"d={d}: postings not reduced enough"
        assert tot_b < base_bytes / 3, f"d={d}: bytes not reduced enough"
        assert tot_b >= prev_bytes, "data read should grow with MaxDistance"
        prev_bytes = tot_b


def test_results_consistent_across_maxdistance(world):
    """d=9 widens the proximity window: strictly more permissive than d=5."""
    table, lex = world
    queries = sample_stop_queries(table, lex, 10, window=2, seed=3)
    engines = {d: ProximitySearchEngine(build_index(table, lex, d), top_k=10_000)
               for d in (5, 9)}
    for q in queries:
        docs = {}
        for d, eng in engines.items():
            r, _ = eng.search_ids(q)
            docs[d] = set(r.doc.tolist())
        assert docs[5] <= docs[9], q


def test_experiment_harness_smoke():
    from benchmarks import paper_experiments

    rep = paper_experiments.run(n_docs=150, mean_doc_len=80, n_queries=12,
                                out_json=None)
    assert set(rep["indexes"]) == {"Idx1", "Idx2", "Idx3", "Idx4"}
    for label in ("Idx2", "Idx3", "Idx4"):
        assert rep["indexes"][label]["postings_reduction_vs_idx1"] > 1.0


def test_dryrun_single_cell_small_mesh():
    """run_cell machinery end to end on an in-process mesh."""
    from repro.configs.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_step

    arch = get_arch("proximity-search")
    mesh = make_mesh((1, 1), ("data", "model"))
    built = build_step(arch, "qt1_p99", mesh)
    compiled = built.lower().compile()
    assert compiled.cost_analysis() is not None
    ma = compiled.memory_analysis()
    assert ma.argument_size_in_bytes > 0
