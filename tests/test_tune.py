"""Autotuner subsystem tests (DESIGN.md §19): workload generators are
deterministic and honor their declared mixes, traces record/replay
bit-identically, successive halving never drops a known-best candidate
on a rigged cost table, and the emitted ServeConfig artifact
round-trips through ``launch/serve.py --config`` loading."""

import dataclasses
import json

import pytest

from repro.core.index_builder import build_index
from repro.core.query import QueryType, classify
from repro.data.corpus import generate_corpus
from repro.launch.mesh import make_mesh
from repro.serving import SearchService, ServeConfig
from repro.tune import (
    Candidate,
    Objective,
    WORKLOAD_GENERATORS,
    attach_arrivals,
    emit_serve_config,
    estimate_workload_us,
    grid,
    load_serve_config,
    load_workload,
    make_workload,
    mixed_workload,
    record_workload,
    stopword_flood,
    successive_halving,
    sweep,
    zipfian_workload,
)

D = 5
BUCKETS = (64, 256, 1024)


@pytest.fixture(scope="module")
def corpus():
    table, lex = generate_corpus(n_docs=60, mean_doc_len=60, vocab_size=400,
                                 seed=7)
    lex.sw_count = 14
    lex.fu_count = 30
    return table, lex


@pytest.fixture(scope="module")
def served(corpus):
    table, lex = corpus
    idx = build_index(table, lex, max_distance=D)
    mesh = make_mesh((1, 1), ("data", "model"))
    return idx, mesh


# -- workload generators ----------------------------------------------------
def test_generators_deterministic_per_seed(corpus):
    table, lex = corpus
    for name in WORKLOAD_GENERATORS:
        a = make_workload(name, table, lex, 16, seed=5)
        b = make_workload(name, table, lex, 16, seed=5)
        assert a.queries == b.queries, name
        c = make_workload(name, table, lex, 16, seed=6)
        assert a.queries != c.queries, f"{name}: seed has no effect"


def test_zipfian_head_heavy(corpus):
    table, lex = corpus
    wl = zipfian_workload(table, lex, 64, alpha=2.0, seed=3)
    mean_id = sum(l for q in wl.queries for l in q) / sum(
        len(q) for q in wl.queries)
    # frequency-rank draws with alpha=2 concentrate far above the
    # uniform mean rank (~vocab/2)
    assert mean_id < lex.n_lemmas / 4, mean_id
    assert all(len(set(q)) == len(q) for q in wl.queries)


def test_stopflood_is_all_qt1(corpus):
    _, lex = corpus
    wl = stopword_flood(lex, 32, seed=4)
    assert all(classify(q, lex) == QueryType.QT1 for q in wl.queries)
    assert wl.meta["type_mix"] == {"qt1": 1.0}
    assert all(l < lex.sw_count for q in wl.queries for l in q)


def test_mixed_workload_honors_declared_mix(corpus):
    table, lex = corpus
    wl = mixed_workload(table, lex, 20, mix={"qt1": 1.0, "qt3": 3.0},
                        window=D, seed=9)
    assert wl.meta["declared_counts"] == {"qt1": 5, "qt3": 15}
    assert len(wl) == 20
    mix = wl.type_mix(lex)
    # the samplers build queries *of the requested type*, so the
    # measured mix matches the declared one
    assert mix.get("qt1", 0.0) == pytest.approx(0.25)
    assert mix.get("qt3", 0.0) == pytest.approx(0.75)


def test_mixed_workload_rejects_bad_mix(corpus):
    table, lex = corpus
    with pytest.raises(ValueError):
        mixed_workload(table, lex, 8, mix={"qt9": 1.0})
    with pytest.raises(ValueError):
        mixed_workload(table, lex, 8, mix={"qt1": 0.0})


def test_record_replay_bit_identical(tmp_path, corpus):
    table, lex = corpus
    wl = attach_arrivals(
        make_workload("mixed", table, lex, 12, seed=2),
        "poisson", qps=50.0, duration_s=0.2, seed=3)
    path = tmp_path / "trace.json"
    record_workload(wl, str(path))
    back = load_workload(str(path))
    assert back.name == wl.name
    assert back.queries == wl.queries
    assert back.arrivals == wl.arrivals
    assert back.meta == wl.meta
    # and a second round trip is byte-identical (pure-JSON payload)
    path2 = tmp_path / "trace2.json"
    record_workload(back, str(path2))
    assert path.read_text() == path2.read_text()


def test_load_workload_rejects_foreign_json(tmp_path):
    path = tmp_path / "other.json"
    path.write_text(json.dumps({"format": "something-else"}))
    with pytest.raises(ValueError):
        load_workload(str(path))


# -- successive halving -----------------------------------------------------
def test_halving_never_drops_known_best():
    # rigged cost table: candidate "best" is cheapest at every rung;
    # every other cost permutes per rung to shake the ordering
    cands = [f"c{i}" for i in range(16)] + ["best"]
    rungs = [
        lambda c, r=r: 0.0 if c == "best" else (hash((c, r)) % 97) + 1.0
        for r in range(3)
    ]
    history = successive_halving(cands, rungs, keep=(8, 4))
    assert [len(rung) for rung in history] == [17, 8, 4]
    assert history[-1][0][0] == "best"
    for rung in history:
        assert any(c == "best" for c, _ in rung), "best was dropped"


def test_halving_keep_floors_and_bounds():
    cands = list("abc")
    rungs = [lambda c: ord(c), lambda c: ord(c)]
    history = successive_halving(cands, rungs, keep=(1,), min_keep=2)
    assert len(history[1]) == 2  # min_keep floors the cut
    history = successive_halving(cands, rungs, keep=(99,))
    assert len(history[1]) == 3  # keep clamped to the field


def test_grid_covers_product_with_unique_ids():
    cands = grid((3, 5), {
        "r_max": [2, 4],
        "k": [{"k_ns": 2, "k_st": 2}, {"k_ns": 3, "k_st": 3}],
    })
    assert len(cands) == 8
    ids = {c.config_id for c in cands}
    assert len(ids) == 8
    multi = cands[0].serve_config()
    assert multi.k_ns == dict(cands[0].overrides)["k_ns"]


# -- ServeConfig serialization + artifact round trip ------------------------
def test_serve_config_json_round_trip():
    cfg = ServeConfig(max_batch=8, buckets=(64, 256), top_k=32, r_max=2,
                      admission=True, max_queue=32, admit_margin=0.7)
    back = ServeConfig.from_json_dict(cfg.to_json_dict())
    assert back == cfg
    with pytest.raises(ValueError):
        ServeConfig.from_json_dict({"no_such_knob": 1})


def test_emitted_artifact_loads_through_launch_serve(tmp_path):
    from repro.launch.serve import build_parser, resolve_config

    cfg = ServeConfig(max_batch=16, top_k=8, r_max=2)
    path = tmp_path / "tuned.json"
    emit_serve_config(str(path), 3, cfg, meta={"workload": "mixed"})
    d, back, meta = load_serve_config(str(path))
    assert (d, back, meta["workload"]) == (3, cfg, "mixed")

    args = build_parser().parse_args(["--config", str(path)])
    d2, cfg2 = resolve_config(args)
    assert (d2, cfg2) == (3, cfg)
    # explicit flags overlay the loaded artifact
    args = build_parser().parse_args(
        ["--config", str(path), "--admission", "--deadline-ms", "25"])
    _, cfg3 = resolve_config(args)
    assert cfg3.admission and cfg3.default_deadline_s == pytest.approx(0.025)
    assert cfg3.max_queue == 4 * cfg.max_batch
    assert dataclasses.replace(cfg3, admission=False, max_queue=None,
                               default_deadline_s=None) == cfg


def test_load_serve_config_rejects_foreign_json(tmp_path):
    path = tmp_path / "other.json"
    path.write_text(json.dumps({"format": "not-a-config"}))
    with pytest.raises(ValueError):
        load_serve_config(str(path))


# -- objective --------------------------------------------------------------
def test_objective_verdict_shape_and_miss_penalty():
    obj = Objective(deadline_s=0.05, target_met_rate=0.99)
    base = {"p50_us": 1000.0, "p95_us": 2000.0, "met_rate_offered": 1.0,
            "index_bytes": 2 << 20}
    good = obj.score(base, config_id="a")
    assert good["config_id"] == "a" and good["met_target_ok"]
    assert good["score"] == pytest.approx(
        sum(good["components"].values()))
    bad = obj.score({**base, "met_rate_offered": 0.5}, config_id="b")
    assert not bad["met_target_ok"]
    assert bad["score"] > good["score"]
    # a bigger index must never score better, all else equal
    big = obj.score({**base, "index_bytes": 200 << 20}, config_id="c")
    assert big["score"] > good["score"]


# -- estimate + sweep against a real service --------------------------------
def test_estimate_workload_us_positive_and_config_sensitive(served, corpus):
    idx, mesh = served
    table, lex = corpus
    wl = make_workload("mixed", table, lex, 12, window=D, seed=13)
    svc = SearchService(idx, mesh, ServeConfig(buckets=BUCKETS, max_batch=8,
                                               top_k=BUCKETS[0]))
    est = estimate_workload_us(svc, wl.queries)
    assert est > 0.0
    # the unit cost model scales with unit_us_per_kslot, so the
    # estimate must too (that is what makes rung 0 discriminating)
    svc2 = SearchService(idx, mesh, ServeConfig(buckets=BUCKETS, max_batch=8,
                                                top_k=BUCKETS[0],
                                                unit_us_per_kslot=10.0))
    assert estimate_workload_us(svc2, wl.queries) > est


def test_sweep_end_to_end_tiny(served, corpus):
    idx, mesh = served
    table, lex = corpus
    wl = make_workload("mixed", table, lex, 8, window=D, seed=17)
    base = ServeConfig(buckets=BUCKETS, max_batch=8, top_k=BUCKETS[0])
    cands = [Candidate(D, axis_values=(("config", "default"),)),
             Candidate(D, overrides=(("r_max", 2),))]
    out = sweep({D: idx}, mesh, cands, wl, base=base,
                objective=Objective(deadline_s=0.5))
    assert out.winner in cands
    assert out.n_candidates == 2
    assert len(out.history) == 2  # estimate rung + one measured rung
    assert out.winner_verdict["config_id"] == out.winner.config_id
    assert out.verdicts and all("score" in v for v in out.verdicts)
    assert out.measurements[out.winner.config_id]["p50_us"] > 0.0
