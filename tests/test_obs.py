"""Observability coverage (DESIGN.md §15): the metrics registry and
tracer primitives in isolation, plus the serving integration — span
trees over a mixed five-type drain, per-response phase breakdowns that
tile the end-to-end latency, Chrome-trace export, est-vs-measured cost
calibration, and snapshot hygiene."""

import json

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.data.corpus import generate_corpus, sample_typed_queries
from repro.launch.mesh import make_mesh
from repro.obs import Histogram, MetricsRegistry, Tracer, chrome_trace
from repro.serving import SearchService, ServeConfig

D = 5
BUCKETS = (256, 1024)
PHASES = ("queue", "plan", "pack", "compress", "compile", "dispatch",
          "execute", "decode")


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    mesh = make_mesh((1, 1), ("data", "model"))
    typed = {
        k: sample_typed_queries(table, lex, 6, k, window=D, seed=3)
        for k in ("qt1", "qt2", "qt3", "qt4", "qt5")
    }
    mixed = [q for qs in typed.values() for q in qs[:3] if q]
    assert len({k for k in typed if typed[k]}) == 5, "need all five types"
    return idx, mesh, mixed


@pytest.fixture(scope="module")
def served(world):
    """One service drained twice (cold then warm) over a five-type mix."""
    idx, mesh, mixed = world
    svc = SearchService(idx, mesh,
                        ServeConfig(buckets=BUCKETS, max_batch=8, top_k=16))
    rounds = []
    for _ in range(2):
        for q in mixed:
            svc.submit(q)
        rounds.append(svc.drain())
    return svc, mixed, rounds


# -- registry / histogram primitives ---------------------------------------
def test_histogram_percentiles_match_numpy():
    rng = np.random.default_rng(7)
    vals = rng.lognormal(mean=2.0, sigma=1.5, size=50)
    h = Histogram("t", capacity=64)
    for v in vals:
        h.observe(v)
    for q in (0, 25, 50, 95, 99, 100):
        assert h.percentile(q) == pytest.approx(np.percentile(vals, q), rel=0)
    snap = h.snapshot()
    assert snap["count"] == 50
    assert snap["sum"] == pytest.approx(vals.sum())
    assert snap["min"] == vals.min() and snap["max"] == vals.max()
    for key, q in (("p50", 50), ("p95", 95), ("p99", 99)):
        assert snap[key] == pytest.approx(float(np.quantile(vals, q / 100)))


def test_histogram_ring_keeps_last_capacity_samples():
    h = Histogram("t", capacity=64)
    vals = np.arange(100, dtype=np.float64)
    for v in vals:
        h.observe(v)
    # exact count/min/max survive eviction; percentiles cover the ring
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["min"] == 0.0 and snap["max"] == 99.0
    assert h.percentile(50) == pytest.approx(np.percentile(vals[-64:], 50))
    assert h.percentile(0) == 36.0  # oldest resident sample


def test_registry_get_or_create_and_type_guard():
    reg = MetricsRegistry()
    c = reg.counter("serve.x")
    reg.inc("serve.x", 2)
    assert c.value == 2 and reg.counter("serve.x") is c
    reg.set("serve.g", 3.5)
    reg.observe("serve.h", 1.0)
    with pytest.raises(TypeError):
        reg.histogram("serve.x")
    assert reg.names("serve.") == ["serve.g", "serve.h", "serve.x"]
    snap = reg.snapshot("serve.")
    assert snap["serve.x"] == 2 and snap["serve.g"] == 3.5
    assert snap["serve.h"]["count"] == 1
    json.dumps(snap)  # plain data only


def test_tracer_bounded_and_disabled():
    tr = Tracer(capacity=4)
    for i in range(6):
        with tr.span("s", i=i):
            pass
    spans = tr.snapshot()
    assert len(spans) == 4 and tr.dropped == 2
    assert [s.args["i"] for s in spans] == [2, 3, 4, 5]  # oldest evicted
    off = Tracer(enabled=False)
    with off.span("s") as sp:
        sp.set(k=1)  # null handle accepts args, keeps nothing
    assert off.snapshot() == []


# -- span trees over a mixed five-type drain -------------------------------
def test_span_tree_nesting_and_ordering(served):
    svc, mixed, rounds = served
    spans = svc.tracer.snapshot()
    roots = [s for s in spans if s.depth == 0]
    assert [s.name for s in roots] == ["drain", "drain"]  # one tree per drain
    # nesting invariant: every non-root span is contained in time by
    # exactly the spans one level up that Perfetto would nest it under
    for s in spans:
        if s.depth == 0:
            continue
        parents = [p for p in spans
                   if p.depth == s.depth - 1 and p.tid == s.tid
                   and p.ts <= s.ts and s.end <= p.end]
        assert parents, f"orphan span {s.name} at depth {s.depth}"
    # siblings under one root never overlap, and snapshot order is by ts
    for root in roots:
        kids = [s for s in spans
                if s.depth == 1 and root.ts <= s.ts and s.end <= root.end]
        assert [s.name for s in kids[:2]] == ["plan", "group"]
        assert any(s.name == "batch" for s in kids)
        for a, b in zip(kids, kids[1:]):
            assert a.end <= b.ts or b.end <= a.ts  # no sibling overlap
    assert all(a.ts <= b.ts for a, b in zip(spans, spans[1:]))
    # batch spans name their step family; their children are phase spans
    fams = {s.args.get("family") for s in spans if s.name == "batch"}
    assert "qt1" in fams and "qt5" in fams
    phase_names = {s.name for s in spans if s.depth == 2}
    assert phase_names <= {"pack", "compress", "compile", "dispatch",
                           "execute", "decode"}
    assert {"pack", "dispatch", "execute", "decode"} <= phase_names


# -- per-response phase breakdowns -----------------------------------------
def test_phase_breakdown_tiles_e2e_latency(served):
    svc, mixed, rounds = served
    for responses in rounds:
        assert len(responses) == len(mixed)
        for r in responses:
            assert set(r.phases) == set(PHASES)
            assert all(v >= 0.0 for v in r.phases.values())
            assert r.finished_at >= r.started_at
            # the phases tile [arrival, finished_at]: their sum agrees
            # with the end-to-end latency within the §15 bound (only the
            # per-request plan timing overlaps the queue window)
            assert sum(r.phases.values()) == pytest.approx(r.e2e_s, rel=0.10)
            assert r.deadline_blame is None  # no deadline was set
    # the same numbers aggregate into serve.phase.* histograms
    phase = svc.metrics_snapshot("serve.phase.")
    n = len(mixed) * len(rounds)
    for name in PHASES:
        assert phase[f"serve.phase.{name}"]["count"] == n


def test_deadline_miss_names_a_phase(world):
    idx, mesh, mixed = world
    svc = SearchService(idx, mesh,
                        ServeConfig(buckets=BUCKETS, max_batch=8, top_k=16))
    tickets = [svc.submit(q, deadline_s=-1.0) for q in mixed]  # unmeetable
    svc.drain()
    blamed = [t.response.deadline_blame for t in tickets]
    assert all(b in PHASES for b in blamed)
    blame = svc.stats_snapshot()["deadlines"]["miss_blame"]
    assert sum(blame.values()) == len(tickets)
    assert set(blame) == set(blamed)


# -- Chrome-trace / Perfetto export ----------------------------------------
def test_chrome_trace_export_is_valid_and_monotonic(served, tmp_path):
    svc, mixed, rounds = served
    obj = svc.trace_snapshot()
    obj = json.loads(json.dumps(obj))  # must survive a JSON round-trip
    events = obj["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    slices = [e for e in events if e["ph"] == "X"]
    assert meta and slices and {e["ph"] for e in events} == {"M", "X"}
    assert any(e["name"] == "process_name" for e in meta)
    for e in slices:
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        assert e["pid"] == 0 and isinstance(e["tid"], int)
    assert all(a["ts"] <= b["ts"] for a, b in zip(slices, slices[1:]))
    # one complete span tree per drained batch round
    assert sum(1 for e in slices if e["name"] == "drain") == len(rounds)
    # write_trace() produces the same object on disk
    path = tmp_path / "trace.json"
    written = svc.write_trace(str(path))
    assert json.loads(path.read_text()) == json.loads(json.dumps(written))


# -- est_step_cost calibration (satellite: planner feedback) ---------------
def test_est_vs_measured_calibration(served):
    svc, mixed, rounds = served
    table = svc.stats_snapshot()["plans"]["est_vs_measured"]
    assert table, "warm drains must populate the measured-cost table"
    for key, row in table.items():
        fam = key.split("/")[0]
        assert fam in ("qt1", "qt2", "qt5")
        assert row["est_step_cost"] > 0 and row["measured_p50_us"] > 0
        assert row["n"] >= 1 and row["us_per_kslot"] > 0
    # explain() stays pure and memoized; the cost view is a fresh copy
    q = mixed[0]
    p = svc.explain(q)
    assert svc.explain(q) is p
    pc = svc.explain(q, costs=True)
    assert pc is not p and pc.measured is not None
    assert pc.est_step_cost == p.est_step_cost
    assert pc.measured["est_step_cost"] == p.est_step_cost
    for entry in pc.measured["executables"].values():
        assert entry["measured_p50_us"] > 0


# -- snapshot hygiene ------------------------------------------------------
def test_stats_snapshot_is_a_deep_consistent_copy(served):
    svc, mixed, rounds = served
    snap = svc.stats_snapshot()
    assert snap["requests"] == len(mixed) * len(rounds)
    # mutating the snapshot must never touch the live stats
    snap["plans"]["routes"]["qt1"] = 10_000
    snap["bucket_hist"]["poison"] = 1
    assert svc.stats["plans"]["routes"].get("qt1") != 10_000
    assert "poison" not in svc.stats["bucket_hist"]
    json.dumps(snap)  # snapshot is plain data


def test_registry_deterministic_across_warm_drains(world):
    idx, mesh, mixed = world
    svc = SearchService(idx, mesh,
                        ServeConfig(buckets=BUCKETS, max_batch=8, top_k=16))
    # two warmup drains: the cold one compiles + fills caches, the first
    # warm one materializes the serve.step.* run-time histograms (first
    # calls are compile-timed, not run-timed)
    for _ in range(2):
        for q in mixed:
            svc.submit(q)
        svc.drain()

    def counters():
        return {n: svc.metrics.get(n).value
                for n in svc.metrics.names()
                if not hasattr(svc.metrics.get(n), "observe")
                and not n.endswith(".bytes")}

    def hist_counts():
        return {n: svc.metrics.get(n).count
                for n in svc.metrics.names()
                if hasattr(svc.metrics.get(n), "observe")}

    deltas = []
    for _ in range(2):
        c0, h0 = counters(), hist_counts()
        for q in mixed:
            svc.submit(q)
        svc.drain()
        c1, h1 = counters(), hist_counts()
        assert set(c1) == set(c0) and set(h1) == set(h0)  # no new names
        deltas.append((
            {n: c1[n] - c0[n] for n in c1},
            {n: h1[n] - h0[n] for n in h1},
        ))
    # warm drains are deterministic: identical counter increments and
    # histogram observation counts, zero compiles, all cache hits
    assert deltas[0] == deltas[1]
    cdelta, hdelta = deltas[0]
    assert all(delta == 0 for n, delta in cdelta.items() if "misses" in n)
    assert cdelta["cache.pack.hits"] > 0
    assert all(delta == 0 for n, delta in hdelta.items()
               if n.startswith("serve.compile."))
    assert hdelta["serve.request.e2e"] == len(mixed)
