"""Cross-engine + oracle validation of the search algorithms.

* Idx1 (ordinary inverted file) vs Idx2 (additional indexes) must return
  the same matching-document sets for QT1 queries;
* all Equalize modes (heap/basic/bulk) must return identical fragments;
* every returned fragment must be valid per the brute-force oracle;
* QT2-QT5 results must cover the oracle's matching docs.
"""

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.lexicon import Lexicon
from repro.core.search import InvertedIndexEngine, ProximitySearchEngine
from repro.data.corpus import TokenTable, generate_corpus

from oracle import fragment_is_valid, matching_docs

D = 5


@pytest.fixture(scope="module")
def small_world():
    table, lex = generate_corpus(n_docs=60, mean_doc_len=60, vocab_size=400, seed=3)
    lex.sw_count = 12
    lex.fu_count = 25
    idx_full = build_index(table, lex, max_distance=D)
    idx_plain = build_index(table, lex, max_distance=D, build_wv=False, build_fst=False, build_nsw=False)
    return table, lex, idx_full, idx_plain


def _stop_queries(table, lex, n, rng):
    out = []
    stop_rows = np.nonzero(table.lemma_ids < lex.sw_count)[0]
    while len(out) < n:
        r = int(rng.choice(stop_rows))
        d0, p0 = int(table.doc_ids[r]), int(table.positions[r])
        m = (table.doc_ids == d0) & (np.abs(table.positions - p0) <= D)
        lems = np.unique(table.lemma_ids[m & (table.lemma_ids < lex.sw_count)])
        if lems.size >= 3:
            k = int(rng.integers(3, min(5, lems.size) + 1))
            out.append(sorted(rng.choice(lems, size=k, replace=False).tolist()))
    return out


def test_qt1_idx1_vs_proximity_docsets(small_world):
    table, lex, idx_full, idx_plain = small_world
    rng = np.random.default_rng(0)
    baseline = InvertedIndexEngine(idx_plain, top_k=10_000)
    prox = ProximitySearchEngine(idx_full, top_k=10_000, equalize_mode="heap")
    for q in _stop_queries(table, lex, 12, rng):
        r1, _ = baseline.search_ids(q)
        r2, _ = prox.search_ids(q)
        docs1 = set(r1.doc.tolist())
        docs2 = set(r2.doc.tolist())
        oracle = matching_docs(table, q, D)
        assert docs1 == oracle, f"Idx1 doc set mismatch for {q}"
        assert docs2 == oracle, f"fst doc set mismatch for {q}"


def test_qt1_equalize_modes_identical(small_world):
    table, lex, idx_full, _ = small_world
    rng = np.random.default_rng(1)
    engines = {
        m: ProximitySearchEngine(idx_full, top_k=10_000, equalize_mode=m)
        for m in ("heap", "basic", "bulk")
    }
    for q in _stop_queries(table, lex, 8, rng):
        results = {}
        for m, eng in engines.items():
            r, _ = eng.search_ids(q)
            results[m] = sorted(zip(r.doc.tolist(), r.start.tolist(), r.end.tolist()))
        assert results["heap"] == results["basic"] == results["bulk"], q


def test_qt1_fragments_valid(small_world):
    table, lex, idx_full, _ = small_world
    rng = np.random.default_rng(2)
    prox = ProximitySearchEngine(idx_full, top_k=10_000)
    for q in _stop_queries(table, lex, 8, rng):
        r, _ = prox.search_ids(q)
        for doc, s, e in zip(r.doc.tolist(), r.start.tolist(), r.end.tolist()):
            assert fragment_is_valid(table, q, D, doc, s, e), (q, doc, s, e)


def _typed_query(table, lex, rng, want):
    """Sample a co-occurring query containing the wanted lemma classes."""
    sw, fu = lex.sw_count, lex.fu_count
    rows = np.arange(table.n_rows)
    for _ in range(4000):
        r = int(rng.choice(rows))
        d0, p0 = int(table.doc_ids[r]), int(table.positions[r])
        m = (table.doc_ids == d0) & (np.abs(table.positions - p0) <= D)
        lems = np.unique(table.lemma_ids[m])
        stop = lems[lems < sw]
        freq = lems[(lems >= sw) & (lems < sw + fu)]
        ordi = lems[lems >= sw + fu]
        if want == "qt2" and freq.size >= 2:
            return sorted(rng.choice(freq, 2, replace=False).tolist())
        if want == "qt3" and ordi.size >= 2:
            return sorted(rng.choice(ordi, 2, replace=False).tolist())
        if want == "qt4" and freq.size >= 1 and ordi.size >= 1:
            return sorted([int(rng.choice(freq)), int(rng.choice(ordi))])
        if want == "qt5" and stop.size >= 1 and (freq.size + ordi.size) >= 2:
            ns = np.concatenate([freq, ordi])
            pick = rng.choice(ns, 2, replace=False).tolist() + [int(rng.choice(stop))]
            return sorted(pick)
    pytest.skip(f"could not sample a {want} query")


@pytest.mark.parametrize("want", ["qt2", "qt3", "qt4", "qt5"])
def test_other_query_types_match_oracle(small_world, want):
    table, lex, idx_full, _ = small_world
    rng = np.random.default_rng({"qt2": 21, "qt3": 22, "qt4": 23, "qt5": 24}[want])
    prox = ProximitySearchEngine(idx_full, top_k=10_000)
    for trial in range(4):
        q = _typed_query(table, lex, rng, want)
        r, _ = prox.search_ids(q)
        got = set(r.doc.tolist())
        anchor = None
        if want == "qt5":
            # QT5 anchors on the rarest non-stop lemma (stop lemmas are
            # resolved from the anchor's NSW records — paper §1.2)
            nonstop = [l for l in q if l >= lex.sw_count]
            counts = {l: int((table.lemma_ids == l).sum()) for l in set(nonstop)}
            anchor = min(sorted(set(nonstop)), key=lambda l: (counts[l], l))
        oracle = matching_docs(table, q, D, anchor=anchor)
        if want == "qt2":
            # QT2 joins pair intervals within 2d of each other — a superset
            # of the single-anchor oracle; oracle docs must all be found.
            assert oracle <= got, (q, oracle - got)
        else:
            assert got == oracle, (q, want)


def test_metrics_reduction_qt1(small_world):
    """The paper's headline: additional indexes read far fewer postings."""
    table, lex, idx_full, idx_plain = small_world
    rng = np.random.default_rng(5)
    baseline = InvertedIndexEngine(idx_plain, top_k=100)
    prox = ProximitySearchEngine(idx_full, top_k=100)
    tot1 = tot2 = 0
    for q in _stop_queries(table, lex, 10, rng):
        _, s1 = baseline.search_ids(q)
        _, s2 = prox.search_ids(q)
        tot1 += s1.postings
        tot2 += s2.postings
    assert tot2 < tot1, "additional indexes should process fewer postings"


def test_full_text_pipeline():
    """End-to-end Table 1 flow over a real-text corpus with lemmatization."""
    from repro.core.lemmatizer import lemmatize_text

    docs_text = [
        "All was fresh around them familiar and yet new tinged with the beauty",
        "Who are you who said the familiar voice in the new fresh morning",
        "The beauty of the fresh morning was new to them all",
        "You said you are the one who was around the familiar places",
    ] * 3
    lemmatized = [lemmatize_text(t) for t in docs_text]
    lex = Lexicon.build(lemmatized, sw_count=8, fu_count=6)
    docs_ids = [[[lex.fl(a) for a in alts] for alts in doc] for doc in lemmatized]
    table = TokenTable.from_lemmatized(docs_ids)
    idx = build_index(table, lex, max_distance=5)
    eng = ProximitySearchEngine(idx, top_k=50)
    res, stats = eng.search("who are you who")
    assert res.size > 0
    assert stats.bytes_read > 0
    # top hit must be one of the docs actually containing the phrase words
    assert int(res.doc[0]) % len(docs_text) in (1, 3)
