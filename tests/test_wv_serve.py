"""Compiled QT2/QT5 serve pipeline (DESIGN.md §12): the device joins
must match the CPU reference engine exactly — over static and segmented
(post-compaction) indexes, across all three payload formats, in
mixed-type drains, and through the uint16 span-overflow fallback."""

import numpy as np
import pytest

from repro.core.index_builder import build_index
from repro.core.jax_search import (
    compress_qt2_batch,
    compress_qt5_batch,
    decode_results,
    make_wv_serve_step,
    pack_qt2_batch,
    pack_qt5_batch,
)
from repro.core.lexicon import Lexicon
from repro.core.query import QueryType, classify
from repro.core.search import ProximitySearchEngine
from repro.data.corpus import (
    TokenTable,
    generate_corpus,
    sample_mixed_queries,
    sample_typed_queries,
)
from repro.index import SegmentedIndex
from repro.launch.mesh import make_mesh
from repro.serving.engine import SearchServingEngine

D = 5
L = 512


@pytest.fixture(scope="module")
def world():
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=D)
    mesh = make_mesh((1, 1), ("data", "model"))
    queries = {
        k: sample_typed_queries(table, lex, 10, k, window=D, seed=3)
        for k in ("qt1", "qt2", "qt3", "qt4", "qt5")
    }
    return table, lex, idx, mesh, queries


def _cpu_sets(idx, qs):
    eng = ProximitySearchEngine(idx, top_k=100_000, equalize_mode="bulk")
    out = []
    for q in qs:
        res, _ = eng.search_ids(q)
        out.append(set(zip(res.doc.tolist(), res.start.tolist(), res.end.tolist())))
    return out


def _decoded_sets(decoded, n):
    return [
        set(zip(decoded[i]["doc"].tolist(), decoded[i]["start"].tolist(),
                decoded[i]["end"].tolist()))
        for i in range(n)
    ]


@pytest.mark.parametrize("payload", ["raw", "delta", "offsets"])
def test_device_qt2_matches_reference(world, payload):
    table, lex, idx, mesh, queries = world
    qs = queries["qt2"]
    assert all(classify(q, lex) == QueryType.QT2 for q in qs)
    batch = pack_qt2_batch(idx, qs, L=L, K=3)
    step = make_wv_serve_step(mesh, "qt2", top_k=256, payload=payload, max_distance=D)
    args = (batch.device_args() if payload == "raw"
            else compress_qt2_batch(batch, delta_g=(payload == "delta")))
    got = _decoded_sets(decode_results(batch, *step(*args)), len(qs))
    for qi, (g, w) in enumerate(zip(got, _cpu_sets(idx, qs))):
        assert g == w, (payload, qi, qs[qi], sorted(g ^ w)[:5])


@pytest.mark.parametrize("payload", ["raw", "delta", "offsets"])
def test_device_qt5_matches_reference(world, payload):
    table, lex, idx, mesh, queries = world
    qs = queries["qt5"]
    assert all(classify(q, lex) == QueryType.QT5 for q in qs)
    batch = pack_qt5_batch(idx, qs, L=L, Kn=4, Ks=4)
    step = make_wv_serve_step(mesh, "qt5", top_k=256, payload=payload,
                              max_distance=D, r_max=4)
    args = (batch.device_args() if payload == "raw"
            else compress_qt5_batch(batch, delta_g=(payload == "delta")))
    got = _decoded_sets(decode_results(batch, *step(*args)), len(qs))
    for qi, (g, w) in enumerate(zip(got, _cpu_sets(idx, qs))):
        assert g == w, (payload, qi, qs[qi], sorted(g ^ w)[:5])


def _resp_set(r):
    return set(zip(r.results["doc"].tolist(), r.results["start"].tolist(),
                   r.results["end"].tolist()))


def test_mixed_drain_matches_cpu_engine(world):
    """A single drain routes QT1/QT2/QT3/QT5 each to its compiled step;
    responses come back in submission order and match the CPU reference
    per request."""
    table, lex, idx, mesh, queries = world
    mixed = [q for k in ("qt1", "qt2", "qt3", "qt5") for q in queries[k][:6]]
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    for q in mixed:
        eng.submit(q)
    resp = eng.drain()
    assert len(resp) == len(mixed)
    want = _cpu_sets(idx, mixed)
    for q, r, w in zip(mixed, resp, want):
        assert _resp_set(r) == w, (q, r.path)
    paths = eng.stats["paths"]
    assert paths["qt1"] >= 6 and paths["qt2"] == 6 and paths["qt5"] == 6
    assert paths["qt34"] == 6 and paths["cpu"] == 0  # the QT3 slice compiles now
    # second (warm-cache) drain is identical
    for q in mixed:
        eng.submit(q)
    warm = eng.drain()
    assert [_resp_set(r) for r in warm] == [_resp_set(r) for r in resp]
    assert eng.stats["pack_cache"]["hits"] > 0


@pytest.mark.parametrize("use_ccache", [True, False])
def test_compressed_mixed_drain_matches_uncompressed(world, use_ccache):
    table, lex, idx, mesh, queries = world
    mixed = [q for k in ("qt1", "qt2", "qt5") for q in queries[k][:6]]
    base = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    comp = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8,
                               top_k=256, compressed=True,
                               use_compressed_cache=use_ccache)
    for round_ in range(2):  # second round serves from the row caches
        for q in mixed:
            base.submit(q)
            comp.submit(q)
        got_b = [_resp_set(r) for r in base.drain()]
        got_c = [_resp_set(r) for r in comp.drain()]
        assert got_b == got_c, round_
    assert comp.stats["compressed_batches"] > 0
    if use_ccache:
        st = comp.stats["compressed_cache"]
        assert st["hits"] > 0 and st["misses"] > 0 and st["bytes"] > 0


def test_segmented_post_compaction_equivalence(world):
    """QT1-QT5 dispatch over a segmented snapshot that went through
    deletes and a forced major compaction must match a CPU engine over
    the same snapshot."""
    table, lex, idx, mesh, queries = world
    seg = SegmentedIndex(lex, max_distance=D, memtable_docs=16)
    for d in table.to_doc_lists():
        seg.add_document(d)
    seg.refresh()
    seg.delete_document(3)
    seg.delete_document(40)
    seg.compact(force=True)
    view = seg.refresh()
    mixed = [q for k in ("qt1", "qt2", "qt3", "qt4", "qt5") for q in queries[k][:5]]
    eng = SearchServingEngine(seg, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    comp = SearchServingEngine(seg, mesh, buckets=(256, 1024), max_batch=8,
                               top_k=256, compressed=True)
    for q in mixed:
        eng.submit(q)
        comp.submit(q)
    got = [_resp_set(r) for r in eng.drain()]
    got_c = [_resp_set(r) for r in comp.drain()]
    want = _cpu_sets(view, mixed)
    assert got == want
    assert got_c == want
    served = {doc for s in got for doc, _, _ in s}
    assert 3 not in served and 40 not in served


def test_cpu_route_for_inexpressible_shapes(world):
    """Queries the compiled steps cannot express (too many (w,v) keys /
    long QT1 splits) fall back to the scalar engine — and still match
    it, because they *are* it."""
    table, lex, idx, mesh, queries = world
    sw, fu = lex.sw_count, lex.fu_count
    long_qt2 = list(range(sw, sw + 8))  # 8 frequent lemmas -> 4 (w,v) keys > k_wv
    long_qt1 = [0, 1, 2, 3, 4, 5, 0]  # len 7 > MaxDistance -> CPU split path
    assert classify(long_qt2, lex) == QueryType.QT2
    assert classify(long_qt1, lex) == QueryType.QT1
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    for q in (long_qt2, long_qt1, []):
        eng.submit(q)
    resp = eng.drain()
    want = _cpu_sets(idx, [long_qt2, long_qt1])
    assert _resp_set(resp[0]) == want[0] and resp[0].path == "cpu"
    assert _resp_set(resp[1]) == want[1] and resp[1].path == "cpu"
    assert resp[2].results["doc"].size == 0 and resp[2].path == "empty"
    assert eng.stats["paths"]["cpu"] == 2


def _overflow_world():
    """A corpus whose hot keys recur in documents so far apart that one
    64-posting delta block spans more than uint16: compressed serving
    must fall back to the offsets format, per key, on every path."""
    sw_count, fu_count = 6, 6
    fu = sw_count  # first frequently-used lemma
    ordinary = sw_count + fu_count
    pattern = [0, 1, 2, fu, fu + 1, ordinary, ordinary + 1]
    filler = [[ordinary + 2] for _ in range(5200)]  # 5200 * stride(14) > 2**16
    docs = [np.array(pattern)] + [np.array(f) for f in filler] + [np.array(pattern)]
    table = TokenTable.from_docs(docs)
    n = ordinary + 3
    counts = np.arange(n, 0, -1) * 100
    dfs = np.minimum(counts, len(docs))
    lex = Lexicon.from_rank_counts(counts=counts, doc_freqs=dfs, n_docs=len(docs),
                                   sw_count=sw_count, fu_count=fu_count)
    idx = build_index(table, lex, max_distance=D)
    queries = [[0, 1, 2], [fu, fu + 1], [0, fu, fu + 1]]
    assert classify(queries[0], lex) == QueryType.QT1
    assert classify(queries[1], lex) == QueryType.QT2
    assert classify(queries[2], lex) == QueryType.QT5
    return idx, queries


@pytest.mark.parametrize("use_ccache", [True, False])
def test_uint16_overflow_falls_back_to_offsets(world, use_ccache):
    _, _, _, mesh, _ = world
    idx, queries = _overflow_world()
    base = SearchServingEngine(idx, mesh, buckets=(256,), max_batch=4, top_k=64)
    comp = SearchServingEngine(idx, mesh, buckets=(256,), max_batch=4, top_k=64,
                               compressed=True, use_compressed_cache=use_ccache)
    for _ in range(2):
        for q in queries:
            base.submit(q)
            comp.submit(q)
        got_b = [_resp_set(r) for r in base.drain()]
        got_c = [_resp_set(r) for r in comp.drain()]
        assert got_b == got_c
    # every query's matches span both pattern docs
    assert all(s for s in got_b)
    assert comp.stats["offset_fallbacks"] >= 3
    assert comp.stats["offset_fallbacks"] == comp.stats["compressed_batches"]


def test_qt5_repeated_lemma_multiplicities(world):
    """Repeated non-stop lemmas exercise the r-nearest (r > 1) join on
    device; repeated stop lemmas exercise cnt >= r on the NSW rows."""
    table, lex, idx, mesh, queries = world
    sw = lex.sw_count
    qs = []
    for q in queries["qt5"]:
        ns = [l for l in q if l >= sw]
        st = [l for l in q if l < sw]
        qs.append(q + [ns[0]])  # duplicate a non-stop lemma
        qs.append(q + [st[0]])  # duplicate a stop lemma
    qs = [q for q in qs if classify(q, lex) == QueryType.QT5][:10]
    eng = SearchServingEngine(idx, mesh, buckets=(256, 1024), max_batch=8, top_k=256)
    for q in qs:
        eng.submit(q)
    resp = eng.drain()
    want = _cpu_sets(idx, qs)
    for q, r, w in zip(qs, resp, want):
        assert _resp_set(r) == w, (q, r.path, sorted(_resp_set(r) ^ w)[:5])
    assert eng.stats["paths"]["qt5"] == len(qs)


def test_mixed_sampler_shapes(world):
    table, lex, idx, mesh, queries = world
    mixed = sample_mixed_queries(table, lex, 15, window=D, seed=7)
    assert len(mixed) == 15
    kinds = {classify(q, lex) for q in mixed}
    assert kinds == {QueryType.QT1, QueryType.QT2, QueryType.QT3,
                     QueryType.QT4, QueryType.QT5}
