"""Per-architecture smoke tests: a REDUCED config of the same family runs
one real step on CPU; outputs must have the right shapes and no NaNs.
The FULL configs are exercised (ShapeDtypeStruct only) by the dry-run."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCH_IDS, get_arch
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_step, materialize_inputs


def _finite(tree):
    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(leaf, np.float32) if hasattr(leaf, "dtype") else np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating):
            assert np.isfinite(arr).all(), "non-finite values in output"


CELLS = []
for _arch in ALL_ARCHS:
    _small = _arch.reduced()
    for _shape in _small.shapes:
        CELLS.append((_arch.arch_id, _shape))


@pytest.fixture(scope="module")
def mesh11():
    return make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch_id,shape_name", CELLS)
def test_arch_smoke(arch_id, shape_name, mesh11):
    arch = get_arch(arch_id).reduced()
    built = build_step(arch, shape_name, mesh11)
    args = materialize_inputs(arch, shape_name, built, seed=1)
    out = built.fn(*args)
    _finite(out)
    shape = arch.shapes[shape_name]
    cfg = arch.model_cfg
    if shape.kind == "train":
        _, _, metrics = out
        assert float(metrics["loss"]) > 0
    elif shape.kind == "prefill":
        logits, caches = out
        assert logits.shape == (shape.dims["global_batch"], cfg.vocab)
        assert caches["k"].shape[0] == cfg.n_layers
    elif shape.kind == "decode":
        logits, caches = out
        assert logits.shape == (shape.dims["global_batch"], cfg.vocab)
    elif arch.family == "search":
        top_s, top_g, top_lo, top_hi = out
        assert top_s.shape[0] == shape.dims["batch"]


def test_train_loss_decreases_lm_smoke(mesh11):
    """Two steps of the smoke LM must reduce loss (the optimizer works)."""
    arch = get_arch("stablelm-1.6b").reduced()
    built = build_step(arch, "train_4k", mesh11)
    params, opt, batch = materialize_inputs(arch, "train_4k", built, seed=2)
    losses = []
    for _ in range(4):
        params, opt, metrics = built.fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


def test_full_param_counts_match_published():
    expected = {
        "stablelm-1.6b": 1.64e9,
        # assigned config (d_ff=13440, gated SwiGLU, untied 92416 vocab)
        # computes to 8.19B; the "7B" name rounds a non-gated-count variant
        "codeqwen1.5-7b": 8.19e9,
        "qwen1.5-32b": 32.5e9,
        "phi3.5-moe-42b-a6.6b": 41.9e9,
        "granite-moe-1b-a400m": 1.33e9,
    }
    for arch_id, want in expected.items():
        got = get_arch(arch_id).model_cfg.param_count()
        assert abs(got - want) / want < 0.12, (arch_id, got, want)
    # MoE active-param counts (the model names say 6.6b / 400m active)
    assert abs(get_arch("phi3.5-moe-42b-a6.6b").model_cfg.active_param_count() - 6.6e9) / 6.6e9 < 0.15
    assert abs(get_arch("granite-moe-1b-a400m").model_cfg.active_param_count() - 4.0e8) / 4.0e8 < 0.25


def test_assigned_archs_all_registered():
    assert len(ASSIGNED_ARCH_IDS) == 10
    for a in ASSIGNED_ARCH_IDS:
        arch = get_arch(a)
        assert arch.shapes, a
        # 4 shape cells per assigned arch (LM archs carry the long_500k skip)
        assert len(arch.shapes) + len(arch.skips) == 4, a


def test_moe_dispatch_matches_dense_reference(mesh11):
    """The capacity-dispatch MoE must match the dense oracle when capacity
    is large enough that nothing drops."""
    from repro.models.moe import MoEConfig, init_moe, moe_block, moe_block_dense_ref

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=8.0)
    key = jax.random.key(0)
    p = init_moe(key, 16, cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16), jnp.float32)
    y, aux = moe_block(p, x, cfg=cfg, mesh=mesh11, dp_axes=("data",))
    y_ref = moe_block_dense_ref(p, x, cfg=cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_egnn_equivariance():
    """E(n) equivariance: rotating+translating inputs rotates+translates
    the coordinate outputs and leaves node features invariant."""
    from dataclasses import replace as drep

    from repro.models import gnn

    cfg = gnn.EGNNConfig(n_layers=2, d_hidden=16, d_feat=8)
    params = gnn.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    N, E = 12, 30
    feats = jnp.asarray(rng.normal(size=(N, 8)), jnp.float32)
    coords = jnp.asarray(rng.normal(size=(N, 3)), jnp.float32)
    src = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    dst = jnp.asarray(rng.integers(0, N, E), jnp.int32)
    mask = jnp.ones(E, jnp.float32)
    # random rotation (QR) + translation
    Q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    Q = jnp.asarray(Q, jnp.float32)
    t = jnp.asarray(rng.normal(size=(1, 3)), jnp.float32)
    h1, x1, _ = gnn.forward(cfg, params, feats, coords, src, dst, mask)
    h2, x2, _ = gnn.forward(cfg, params, feats, coords @ Q.T + t, src, dst, mask)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(x1 @ Q.T + t), np.asarray(x2), rtol=2e-3, atol=2e-3)
