import numpy as np

from repro.core.lemmatizer import lemmatize_text, lemmatize_word, tokenize
from repro.core.lexicon import Lexicon, LemmaType, UNKNOWN_FL
from repro.core.query import (
    QueryType,
    build_subqueries,
    classify,
    select_fst_keys,
    select_wv_keys,
)


def test_paper_lemmatization_examples():
    # §1.1: "tinged" -> [ting, tinge]; "are" -> [are, be]; "mine" -> [mine, my]
    assert set(lemmatize_word("tinged")) == {"ting", "tinge"}
    assert set(lemmatize_word("are")) == {"are", "be"}
    assert set(lemmatize_word("mine")) == {"mine", "my"}
    assert lemmatize_word("was") == ["be"]
    assert lemmatize_word("familiar") == ["familiar"]
    # excerpt from "Beyond the City" (paper §1.1)
    lems = lemmatize_text("All was fresh around them, familiar and yet new, tinged with the beauty")
    flat = [l for alts in lems for l in alts]
    for expected in ["all", "be", "fresh", "around", "they", "familiar", "and", "yet", "new", "ting", "tinge", "with", "the", "beauty"]:
        assert expected in flat, expected


def test_fl_list_ordering_and_types():
    docs = [["a"] * 50 + ["b"] * 20 + ["c"] * 5 + ["d"]]
    lex = Lexicon.build(docs, sw_count=1, fu_count=1)
    assert lex.lemmas[0] == "a" and lex.fl("a") == 0
    assert lex.type_of("a") == LemmaType.STOP
    assert lex.type_of("b") == LemmaType.FREQUENT
    assert lex.type_of("c") == LemmaType.ORDINARY
    assert lex.fl("zzz") == UNKNOWN_FL  # the paper's "~"


def test_lexicon_save_load(tmp_path):
    docs = [["x", "y", "x"], ["y", "x", "z"]]
    lex = Lexicon.build(docs, sw_count=1, fu_count=1)
    lex.save(tmp_path / "lex.json")
    lex2 = Lexicon.load(tmp_path / "lex.json")
    assert lex2.lemmas == lex.lemmas
    assert lex2.fl("y") == lex.fl("y")
    assert np.array_equal(lex2.counts, lex.counts)


def test_classify_query_types():
    docs = [["s"] * 100 + ["f"] * 50 + ["o"] * 2]
    lex = Lexicon.build(docs, sw_count=1, fu_count=1)
    s, f, o = lex.fl("s"), lex.fl("f"), lex.fl("o")
    assert classify([s, s], lex) == QueryType.QT1
    assert classify([f], lex) == QueryType.QT2
    assert classify([o, o], lex) == QueryType.QT3
    assert classify([f, o], lex) == QueryType.QT4
    assert classify([s, o], lex) == QueryType.QT5
    assert classify([s, f, o], lex) == QueryType.QT5


def test_select_fst_keys_paper_example():
    # FL numbers from the paper: who=293, are=268, you=47 (1-based there;
    # only the relative order matters).
    who, are, you = 293, 268, 47
    f, keys = select_fst_keys([who, are, you, who])
    assert f == you
    assert set(keys) == {(you, are, who), (you, who, who)}


def test_select_fst_keys_distinct_lemmas_no_spurious_multiplicity():
    f, keys = select_fst_keys([0, 3, 7, 8])
    assert f == 0
    # no key may demand two occurrences of a lemma the query has once
    for _, s, t in keys:
        assert s != t
    covered = {l for k in keys for l in k[1:]}
    assert covered == {3, 7, 8}


def test_select_fst_keys_three_lemmas():
    f, keys = select_fst_keys([5, 2, 9])
    assert f == 2 and keys == [(2, 5, 9)]


def test_select_wv_keys():
    assert select_wv_keys([4, 1, 3]) == [(1, 3), (1, 4)]
    assert select_wv_keys([2, 8]) == [(2, 8)]


def test_subquery_expansion_who_are_you_who():
    # Table 1: two sub-queries (are -> are|be)
    docs = [
        (["who"] * 30 + ["are"] * 25 + ["be"] * 40 + ["you"] * 35) * 2
    ]
    lex = Lexicon.build(docs, sw_count=4, fu_count=0)
    subs = build_subqueries("who are you who", lex)
    assert len(subs) == 2
    seqs = {tuple(lex.lemma_of(i) for i in s.lemma_ids) for s in subs}
    assert ("who", "are", "you", "who") in seqs
    assert ("who", "be", "you", "who") in seqs
    assert all(s.qtype == QueryType.QT1 for s in subs)
