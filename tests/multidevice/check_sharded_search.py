"""Multi-device integration check: doc-sharded QT1 serving on a (2,4) mesh
must agree with the single-device reference engine. Run via
test_jax_search.py::test_doc_sharded_serving_multidevice."""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # simulated host mesh:
# never probe real accelerators (TPU metadata probing hangs off-GCP)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core.index_builder import build_index  # noqa: E402
from repro.core.jax_search import (  # noqa: E402
    decode_results,
    make_qt1_serve_step,
    pack_qt1_batch,
)
from repro.core.search import ProximitySearchEngine  # noqa: E402
from repro.data.corpus import generate_corpus, sample_stop_queries  # noqa: E402


def main() -> None:
    table, lex = generate_corpus(n_docs=80, mean_doc_len=70, vocab_size=500, seed=11)
    lex.sw_count = 14
    lex.fu_count = 30
    idx = build_index(table, lex, max_distance=5)
    queries = sample_stop_queries(table, lex, 16, window=5, seed=4)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    step = jax.jit(make_qt1_serve_step(mesh, top_k=512))
    batch = pack_qt1_batch(idx, queries, L=2048, K=2, doc_shards=4)
    decoded = decode_results(batch, *step(*batch.device_args()))

    eng = ProximitySearchEngine(idx, top_k=100_000, equalize_mode="bulk")
    for qi, q in enumerate(queries):
        res, _ = eng.search_ids(q)
        want = set(zip(res.doc.tolist(), res.start.tolist(), res.end.tolist()))
        got = set(
            zip(
                decoded[qi]["doc"].tolist(),
                decoded[qi]["start"].tolist(),
                decoded[qi]["end"].tolist(),
            )
        )
        assert got == want, (qi, q, got ^ want)
    print("SHARDED_SEARCH_OK")


if __name__ == "__main__":
    main()
