"""Multi-device check: compressed-DP training (int8 + topk) vs exact DP."""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")  # simulated host mesh:
# never probe real accelerators (TPU metadata probing hangs off-GCP)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.launch.mesh import make_mesh  # noqa: E402
from repro.train.grad_compression import (  # noqa: E402
    init_error_state,
    make_compressed_dp_train_step,
)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state  # noqa: E402


def main() -> None:
    mesh = make_mesh((4,), ("data",))
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(8, 1)).astype(np.float32)

    def loss_fn(params, batch):
        x, y = batch["x"], batch["y"]
        pred = x @ params["w"]
        return jnp.mean((pred - y) ** 2)

    params0 = {"w": jnp.zeros((8, 1), jnp.float32)}
    opt_cfg = AdamWConfig(lr=5e-2, weight_decay=0.0)

    def make_batch(step):
        r = np.random.default_rng(step)
        x = r.normal(size=(64, 8)).astype(np.float32)
        y = x @ w_true + 0.01 * r.normal(size=(64, 1)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    results = {}
    for scheme in ("exact", "int8", "topk"):
        step = make_compressed_dp_train_step(loss_fn, opt_cfg, mesh, "data", scheme, topk_frac=0.5)
        params = jax.tree.map(lambda x: x, params0)
        opt = init_opt_state(params)
        err = init_error_state(params)
        losses = []
        for i in range(40):
            params, opt, err, m = step(params, opt, err, make_batch(i))
            losses.append(float(m["loss"]))
        results[scheme] = losses
    # all schemes must converge on this convex problem
    for scheme, losses in results.items():
        assert losses[-1] < 0.05 * losses[0], (scheme, losses[0], losses[-1])
    # compressed final loss within a modest factor of exact
    assert results["int8"][-1] < results["exact"][-1] * 20 + 1e-3
    assert results["topk"][-1] < results["exact"][-1] * 20 + 1e-3
    print("COMPRESSED_DP_OK", {k: round(v[-1], 5) for k, v in results.items()})


if __name__ == "__main__":
    main()
