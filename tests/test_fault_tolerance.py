"""Checkpoint/restart, elastic restore, straggler detection, and
compressed gradient sync."""

import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.checkpoint import (
    AsyncCheckpointer,
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.fault_tolerance import (
    FailureInjector,
    InjectedFailure,
    StragglerDetector,
    TrainSupervisor,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layers": {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)},
        "step": jnp.asarray(3, jnp.int32),
        "list": [jnp.ones(2), jnp.zeros(3)],
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree, {"note": "x"})
    assert latest_step(tmp_path) == 7
    restored, manifest = restore_checkpoint(tmp_path, jax.tree.map(jnp.zeros_like, tree))
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_partial_write(tmp_path):
    """A stale .tmp dir (crash mid-write) must not be visible as latest."""
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    (tmp_path / "step_00000002.tmp").mkdir()
    assert latest_step(tmp_path) == 1


def test_checkpoint_gc(tmp_path):
    tree = _tree()
    for s in range(6):
        save_checkpoint(tmp_path, s, tree)
    gc_checkpoints(tmp_path, keep=2)
    assert latest_step(tmp_path) == 5
    remaining = sorted(d.name for d in tmp_path.iterdir())
    assert len(remaining) == 2


def test_elastic_restore_resharded(tmp_path):
    """Save unsharded, restore onto a (1,2)-mesh sharding."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_mesh

    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(tmp_path, 0, tree)
    mesh = make_mesh((1, 1), ("data", "model"))
    restored, _ = restore_checkpoint(
        tmp_path, tree, mesh=mesh, pspecs={"w": P("model", None)}
    )
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (0, 1, 2):
        ck.save(s, tree)
    ck.wait()
    assert latest_step(tmp_path) == 2


def test_supervisor_restart_recovers_exactly(tmp_path):
    """Inject failures; the run must complete with identical final state
    to a failure-free run (checkpoint + deterministic replay)."""

    def mk_sup(inject, ckpt_dir):
        def init():
            return {"x": jnp.zeros(4), "n": jnp.asarray(0, jnp.int32)}

        def batch_fn(step):
            rng = np.random.default_rng(step)
            return jnp.asarray(rng.normal(size=4), jnp.float32)

        def step_fn(state, batch):
            new = {"x": state["x"] + batch, "n": state["n"] + 1}
            return new, {"loss": float(jnp.sum(new["x"] ** 2))}

        return TrainSupervisor(
            step_fn, batch_fn, init, ckpt_dir, ckpt_every=5,
            injector=FailureInjector(inject),
        )

    sup_clean = mk_sup({}, tmp_path / "clean")
    rep_clean = sup_clean.run(23)

    sup_fail = mk_sup({7: "node_loss", 12: "preemption", 19: "oom"}, tmp_path / "faulty")
    rep_fail = sup_fail.run(23)
    assert rep_fail.restarts == 3
    assert rep_fail.final_step == rep_clean.final_step == 22
    # bit-exact final loss despite three crashes
    assert rep_fail.losses[-1] == rep_clean.losses[-1]


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def init():
        return {"x": jnp.zeros(2)}

    sup = TrainSupervisor(
        step_fn=lambda s, b: (s, {"loss": 1.0}),
        batch_fn=lambda step: None,
        init_state_fn=init,
        ckpt_dir=tmp_path,
        max_restarts=2,
        injector=FailureInjector({0: "a", 1: "b", 2: "c", 3: "d"}),
    )
    # failures re-fire at steps never checkpointed past -> exhausts retries
    sup.injector.fired = set()

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 0:
                raise InjectedFailure("always")

    sup.injector = AlwaysFail()
    with pytest.raises(RuntimeError):
        sup.run(5)


def test_straggler_detection():
    det = StragglerDetector(window=16, tolerance=2.0, warmup=4)
    for i in range(10):
        det.observe(i, 0.10)
    ev = det.observe(10, 0.35)
    assert ev is not None and ev.step == 10
    assert det.observe(11, 0.11) is None


def test_int8_compression_accuracy():
    from repro.train.grad_compression import dequantize_int8, quantize_int8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.51 + 1e-6


def test_topk_error_feedback_invariant():
    """g + err_old == scattered(sel) + err_new (nothing is lost)."""
    from repro.train.grad_compression import topk_sparsify

    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = jnp.asarray(rng.normal(size=(64,)) * 0.1, jnp.float32)
    sel, idx, new_err = topk_sparsify(g, err, k=8)
    dense = np.zeros(64, np.float32)
    dense[np.asarray(idx)] = np.asarray(sel)
    np.testing.assert_allclose(
        np.asarray(g) + np.asarray(err), dense + np.asarray(new_err), rtol=1e-6
    )


def test_compressed_dp_train_step_multidevice():
    """int8-compressed DP training on 4 fake devices matches the exact-DP
    loss trajectory to within quantization noise (subprocess: needs >1
    device)."""
    import subprocess
    import sys
    from pathlib import Path

    script = Path(__file__).parent / "multidevice" / "check_compressed_dp.py"
    env = dict(
        PYTHONPATH=str(Path(__file__).parent.parent / "src"),
        PATH="/usr/bin:/bin", HOME="/root",
    )
    proc = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True, env=env, timeout=300
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "COMPRESSED_DP_OK" in proc.stdout
