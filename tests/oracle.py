"""Brute-force oracles for search semantics (see core/search.py docstring).

The oracle answers: which documents match a sub-query? A document matches
iff there is an occurrence `a` of the anchor lemma (the smallest lemma id
in the query) such that every query lemma has the required number of
*distinct* positions within MaxDistance of `a` (the anchor's own position
counts for its lemma).
"""

from __future__ import annotations

import numpy as np

from repro.data.corpus import TokenTable


def _doc_positions(table: TokenTable, doc: int, lemma: int) -> np.ndarray:
    m = (table.doc_ids == doc) & (table.lemma_ids == lemma)
    return np.unique(table.positions[m])


def matching_docs(table: TokenTable, lemma_ids: list[int], d: int, anchor: int | None = None) -> set[int]:
    mult: dict[int, int] = {}
    for l in lemma_ids:
        mult[l] = mult.get(l, 0) + 1
    if anchor is None:
        anchor = min(mult)  # QT1 rule: most frequent lemma
    docs = set()
    cand_docs = np.unique(table.doc_ids[table.lemma_ids == anchor])
    for doc in cand_docs.tolist():
        a_pos = _doc_positions(table, doc, anchor)
        per_lemma = {l: _doc_positions(table, doc, l) for l in mult}
        for a in a_pos.tolist():
            ok = True
            for l, r in mult.items():
                pos = per_lemma[l]
                within = pos[(pos >= a - d) & (pos <= a + d)]
                if within.size < r:
                    ok = False
                    break
            if ok:
                docs.add(int(doc))
                break
    return docs


def matching_anchor_count(table: TokenTable, lemma_ids: list[int], d: int) -> int:
    """Total matching anchor occurrences across the corpus."""
    mult: dict[int, int] = {}
    for l in lemma_ids:
        mult[l] = mult.get(l, 0) + 1
    anchor = min(mult)
    total = 0
    cand_docs = np.unique(table.doc_ids[table.lemma_ids == anchor])
    for doc in cand_docs.tolist():
        a_pos = _doc_positions(table, doc, anchor)
        per_lemma = {l: _doc_positions(table, doc, l) for l in mult}
        for a in a_pos.tolist():
            ok = True
            for l, r in mult.items():
                pos = per_lemma[l]
                within = pos[(pos >= a - d) & (pos <= a + d)]
                if within.size < r:
                    ok = False
                    break
            if ok:
                total += 1
    return total


def fragment_is_valid(table: TokenTable, lemma_ids: list[int], d: int, doc: int, start: int, end: int) -> bool:
    """Every query lemma occurs (with multiplicity) inside [start,end] and
    the fragment is no wider than the 2*MaxDistance guarantee."""
    if end - start > 2 * d:
        return False
    mult: dict[int, int] = {}
    for l in lemma_ids:
        mult[l] = mult.get(l, 0) + 1
    for l, r in mult.items():
        pos = _doc_positions(table, doc, l)
        inside = pos[(pos >= start) & (pos <= end)]
        if inside.size < r:
            return False
    return True
